"""Pallas kernel: tiled semiring matrix multiply for graph queries.

One kernel body, three semirings — the closures ``repro.graph`` iterates
to answer reachability / shortest-path / widest-path questions over the
dense process graph:

=============  ==================================  =======================
semiring       ``C[i, j]``                         graph meaning
=============  ==================================  =======================
``plus_times`` ``sum_k A[i, k] * B[k, j]``         path *counting* (and the
                                                   0/1 boolean closure once
                                                   the caller thresholds)
``min_plus``   ``min_k A[i, k] + B[k, j]``         shortest-path relaxation
``max_min``    ``max_k min(A[i, k], B[k, j])``     widest-path (bottleneck)
=============  ==================================  =======================

Tiling follows ``kernels.segment_ops.pair_count``: the output is cut into
``block_m x block_n`` tiles (grid axes i, j) and the contraction axis into
``block_k`` tiles (grid axis k — innermost, so each output block stays
resident in VMEM across its accumulation).  ``plus_times`` rides the MXU
(``jnp.dot``); the tropical semirings are VPU broadcast reductions over a
narrow ``block_k`` (the (bm, bk, bn) candidate tensor bounds VMEM).

Exactness: ``min``/``max`` are order-insensitive and ``a + b`` /
``min(a, b)`` are single ops computed identically on every lowering, so
the tropical products are *bitwise* equal to the XLA oracle regardless of
tile shape.  ``plus_times`` accumulates f32 partial sums per k-tile —
exact (hence bitwise) for integer-valued operands while per-cell sums stay
below 2^24, which covers every 0/1 closure and count matrix here; the
dispatch layer documents the inexact-float caveat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEMIRINGS = ("plus_times", "min_plus", "max_min")

# additive identity of each semiring: the init value of an output tile and
# the padding value that can never win a reduction
IDENTITY = {"plus_times": 0.0,
            "min_plus": float("inf"),
            "max_min": float("-inf")}


def _kernel(a_ref, b_ref, out_ref, *, semiring):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, IDENTITY[semiring])

    a = a_ref[...]                              # (bm, bk)
    b = b_ref[...]                              # (bk, bn)
    if semiring == "plus_times":
        out_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)
    elif semiring == "min_plus":
        cand = jnp.min(a[:, :, None] + b[None, :, :], axis=1)
        out_ref[...] = jnp.minimum(out_ref[...], cand)
    else:                                       # max_min
        cand = jnp.max(jnp.minimum(a[:, :, None], b[None, :, :]), axis=1)
        out_ref[...] = jnp.maximum(out_ref[...], cand)


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


@functools.partial(jax.jit, static_argnames=("semiring", "block_m", "block_n",
                                             "block_k", "interpret"))
def semiring_matmul_pallas(a: jax.Array, b: jax.Array,
                           semiring: str = "plus_times", *,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """(M, N) float32 semiring product of ``a`` (M, K) and ``b`` (K, N).

    Inputs are padded with the semiring identity (pad rows/columns can
    never win a min/max and contribute 0 to a sum), the product runs on
    the padded tiles, and the (M, N) corner is sliced back out.
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; one of {SEMIRINGS}")
    if block_k is None:
        # MXU dot wants deep tiles; the (bm, bk, bn) broadcast wants thin
        block_k = 128 if semiring == "plus_times" else 8
    m, kk = a.shape
    _, n = b.shape
    ident = IDENTITY[semiring]
    mp = _round_up(m, block_m)
    np_ = _round_up(n, block_n)
    kp = _round_up(kk, block_k)
    ap = jnp.pad(a.astype(jnp.float32), ((0, mp - m), (0, kp - kk)),
                 constant_values=ident)
    bp = jnp.pad(b.astype(jnp.float32), ((0, kp - kk), (0, np_ - n)),
                 constant_values=ident)
    # min_plus inputs must be finite-or-+inf (inf + inf = inf is a safe
    # pad; a -inf entry meeting the +inf pad would NaN) — the graph
    # closures only ever feed nonnegative weights with +inf for "no edge"
    out = pl.pallas_call(
        functools.partial(_kernel, semiring=semiring),
        grid=(mp // block_m, np_ // block_n, kp // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
