"""Public entry points for the graph semiring primitives — backend dispatched.

``semiring_matmul`` is the one primitive (Pallas tiles / XLA reference,
selected like ``kernels.segment_ops`` via ``core.backend``); the closure
helpers below iterate it by repeated squaring — ``ceil(log2(n))`` products
instead of the n relaxation sweeps of Floyd–Warshall, which is what puts
all-pairs graph queries on the MXU's terms:

* :func:`bool_closure` — k-step boolean reachability.  The 0/1 operands
  ride the ``plus_times`` MXU product and are re-thresholded after every
  multiply, so values stay in {0, 1} and the closure is exact (hence
  bitwise across lowerings) at any k.
* :func:`minplus_closure` — all-pairs shortest distances over a weight
  matrix with ``+inf`` marking absent edges and a zero diagonal (the
  min-plus identity makes D ⊗ D the "paths of ≤ 2x the hops" relaxation).
* :func:`maxmin_closure` — all-pairs widest (bottleneck) capacities over a
  capacity matrix with ``-inf`` marking absent edges and ``+inf`` on the
  diagonal.

Tropical closures are bitwise identical across lowerings for any weights;
with integer-valued weights they are also exactly the NumPy
Floyd–Warshall result (every candidate sum is exact below 2^24), which
the graph benchmark asserts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import semiring_matmul_ref
from .semiring import SEMIRINGS, semiring_matmul_pallas


def _backend():
    # deferred for the same reason as segment_ops.ops: core.backend's
    # parent package would re-enter this package mid-init
    from repro.core import backend

    return backend


def semiring_matmul(a: jax.Array, b: jax.Array,
                    semiring: str = "plus_times", *,
                    impl: str | None = None, **blocks) -> jax.Array:
    """(M, N) float32 semiring product of ``a @ b`` (see module docstring).

    ``impl`` forces a lowering; otherwise ``core.backend.resolve()`` picks
    (Pallas on TPU, the XLA reference elsewhere — same contract as the
    segment primitives).
    """
    if semiring not in SEMIRINGS:
        raise ValueError(f"unknown semiring {semiring!r}; one of {SEMIRINGS}")
    be = _backend()
    chosen = be.resolve(impl)
    if chosen == "pallas":
        return semiring_matmul_pallas(a, b, semiring,
                                      interpret=be.interpret_mode(), **blocks)
    if chosen == "xla":
        return semiring_matmul_ref(a, b, semiring)
    raise ValueError(f"unknown semiring_matmul impl {chosen!r}")


def _steps(n: int, k: int) -> int:
    # squarings needed for a horizon of k edges on an n-node graph
    import math

    k = max(1, min(int(k), max(n - 1, 1)))
    return max(0, math.ceil(math.log2(k)))


def _or_and(x: jax.Array, y: jax.Array, impl: str | None) -> jax.Array:
    # boolean AND-OR product as a thresholded 0/1 MXU matmul: path counts
    # are exact integers below 2^24, so ``> 0`` recovers the exact OR
    return semiring_matmul(x.astype(jnp.float32), y.astype(jnp.float32),
                           "plus_times", impl=impl) > 0


def bool_closure(adj: jax.Array, k: int | None = None, *,
                 impl: str | None = None) -> jax.Array:
    """(N, N) bool: can j be reached from i in **at most** k steps?

    ``k=None`` (or k >= N-1) is the full transitive-reflexive closure —
    repeated squaring of the reflexive seed ``I | A`` (monotone: after s
    squarings the horizon is 2^s edges, and the closure saturates).  A
    finite k runs binary exponentiation of ``(I | A)^k`` instead, which
    never overshoots a non-power-of-two horizon.
    """
    n = adj.shape[0]
    base = jnp.eye(n, dtype=bool) | adj.astype(bool)
    if k is None:
        reach = base
        for _ in range(_steps(n, n - 1)):
            reach = _or_and(reach, reach, impl)
        return reach
    e = min(max(int(k), 0), max(n - 1, 1))
    acc = jnp.eye(n, dtype=bool)
    sq = base
    while e:
        if e & 1:
            acc = _or_and(acc, sq, impl)
        e >>= 1
        if e:
            sq = _or_and(sq, sq, impl)
    return acc


def minplus_closure(w: jax.Array, *, impl: str | None = None) -> jax.Array:
    """All-pairs shortest distances of a weight matrix (``+inf`` = no edge,
    diagonal forced to 0).  ``ceil(log2(n-1))`` min-plus squarings."""
    n = w.shape[0]
    d = jnp.where(jnp.eye(n, dtype=bool), 0.0, w.astype(jnp.float32))
    for _ in range(_steps(n, n - 1)):
        d = semiring_matmul(d, d, "min_plus", impl=impl)
    return d


def maxmin_closure(cap: jax.Array, *, impl: str | None = None) -> jax.Array:
    """All-pairs widest-path capacities (``-inf`` = no edge, diagonal
    forced to ``+inf`` — the max-min identity)."""
    n = cap.shape[0]
    d = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, cap.astype(jnp.float32))
    for _ in range(_steps(n, n - 1)):
        d = semiring_matmul(d, d, "max_min", impl=impl)
    return d
