"""XLA reference lowerings for the graph semiring products (parity oracles).

``plus_times`` is a plain ``jnp.dot``; the tropical semirings are the
row-blocked broadcast reduction — blocked so the (rows, K, N) candidate
tensor never materializes for large graphs.  Tropical products are bitwise
identical to the Pallas tiles for any block shape (min/max are
order-insensitive; each candidate ``a + b`` / ``min(a, b)`` is one op
computed identically), which is what the parity tests assert.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("semiring", "block_m"))
def semiring_matmul_ref(a: jax.Array, b: jax.Array,
                        semiring: str = "plus_times", *,
                        block_m: int = 16) -> jax.Array:
    """(M, N) float32 semiring product — the reference scatter-free path."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if semiring == "plus_times":
        return jnp.dot(a, b, preferred_element_type=jnp.float32)
    if semiring not in ("min_plus", "max_min"):
        raise ValueError(f"unknown semiring {semiring!r}")
    m = a.shape[0]
    pad = (-m) % block_m
    ident = jnp.inf if semiring == "min_plus" else -jnp.inf
    ap = jnp.pad(a, ((0, pad), (0, 0)), constant_values=ident)
    blocks = ap.reshape(-1, block_m, a.shape[1])

    def one(ab):
        if semiring == "min_plus":
            return jnp.min(ab[:, :, None] + b[None, :, :], axis=1)
        return jnp.max(jnp.minimum(ab[:, :, None], b[None, :, :]), axis=1)

    out = jax.lax.map(one, blocks)
    return out.reshape(-1, b.shape[1])[:m]
