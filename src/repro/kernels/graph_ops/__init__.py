"""Graph semiring primitives — dense matrix queries over process graphs,
lowered twice (Pallas MXU/VPU tiles + XLA reference) behind the same
``repro.core.backend`` dispatch as the segmented primitives."""
from . import ops, ref
from .ops import bool_closure, maxmin_closure, minplus_closure, semiring_matmul
from .ref import semiring_matmul_ref
from .semiring import SEMIRINGS, semiring_matmul_pallas

__all__ = [
    "ops", "ref",
    "semiring_matmul", "bool_closure", "minplus_closure", "maxmin_closure",
    "semiring_matmul_pallas", "semiring_matmul_ref", "SEMIRINGS",
]
