"""Pallas kernel: sum/min/max over *sorted, consecutive* segment ids.

The event stream arrives sorted by (case, time), so segment ids are a
non-decreasing run ``0,0,1,2,2,2,...`` — a tile of ``block_e`` events can
touch at most ``block_e`` *consecutive* segments.  Each grid step therefore
reduces its tile into a local one-hot window (VPU masked reduction) and
read-modify-writes one dynamic ``block_e``-wide slice of the output, which
stays resident in VMEM across the sequential grid:

    out[seg] = op(out[seg], reduce_op over tile rows with that seg)

Work is O(N * block_e) independent of the number of segments (a dense
one-hot over all segments would be O(N * S)).  Out-of-range ids (< 0 or
>= num_segments) are dropped, matching ``.at[...].op(mode="drop")``.

Contract: ids must be consecutive within their sorted run (as produced by
``ops.segment_ids_sorted`` / ``engine.global_segments``); ids with gaps
wider than ``block_e`` inside one tile would fall outside the window.
Validated in interpret mode on CPU; the TPU lowering runs the same body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _ident_scalar(op: str, dtype):
    """Python-scalar reduction identity (kernels cannot capture arrays)."""
    d = np.dtype(dtype)
    if op == "sum":
        return d.type(0).item()
    if np.issubdtype(d, np.floating):
        return float("inf") if op == "min" else float("-inf")
    info = np.iinfo(d)
    return info.max if op == "min" else info.min


def _kernel(seg_ref, val_ref, out_ref, *, op, num_segments, ident):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    seg = seg_ref[...]                                   # (W,) int32
    val = val_ref[...]                                   # (W,)
    w = seg.shape[0]
    s_pad = out_ref.shape[0]
    base = jnp.clip(seg[0], 0, s_pad - w)
    local = seg - base
    ok = (seg >= 0) & (seg < num_segments) & (local >= 0) & (local < w)
    slots = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    oh = (local.reshape(-1, 1) == slots) & ok.reshape(-1, 1)   # (W, W)
    cells = jnp.where(oh, val.reshape(-1, 1), ident)
    if op == "sum":
        contrib = cells.sum(axis=0)
    elif op == "min":
        contrib = cells.min(axis=0)
    else:
        contrib = cells.max(axis=0)
    cur = out_ref[pl.ds(base, w)]
    if op == "sum":
        new = cur + contrib
    elif op == "min":
        new = jnp.minimum(cur, contrib)
    else:
        new = jnp.maximum(cur, contrib)
    out_ref[pl.ds(base, w)] = new


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "op", "block_e", "interpret"))
def segment_reduce_pallas(values: jax.Array, segment_ids: jax.Array,
                          num_segments: int, op: str = "sum", *,
                          block_e: int = 512, interpret: bool = True) -> jax.Array:
    """(num_segments,) reduction of ``values`` by sorted ``segment_ids``."""
    n = values.shape[0]
    ident = _ident_scalar(op, values.dtype)
    if n == 0:
        return jnp.full((num_segments,), ident, values.dtype)
    pad_e = (-n) % block_e
    seg = jnp.pad(segment_ids.astype(jnp.int32), (0, pad_e), constant_values=-1)
    val = jnp.pad(values, (0, pad_e), constant_values=ident)
    # output window must fit: S_pad >= block_e, lane-aligned
    s_pad = max(block_e, ((num_segments + 127) // 128) * 128)
    ne = (n + pad_e) // block_e

    out = pl.pallas_call(
        functools.partial(_kernel, op=op, num_segments=num_segments,
                          ident=ident),
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda k: (k,)),
            pl.BlockSpec((block_e,), lambda k: (k,)),
        ],
        out_specs=pl.BlockSpec((s_pad,), lambda k: (0,)),
        out_shape=jax.ShapeDtypeStruct((s_pad,), values.dtype),
        interpret=interpret,
    )(seg, val)
    return out[:num_segments]
