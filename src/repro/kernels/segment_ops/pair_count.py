"""Pallas kernel: weighted (src, dst) pair counting on the MXU.

The generalization of the DFG-count kernel to any rectangular
(src, dst, weight) triple — directly-follows edges, performance-overlay
pairs, or any §5.4-style co-occurrence count:

    C = sum_i w_i * e[src_i] e[dst_i]^T  =  (onehot(src) * w)^T @ onehot(dst)

The systolic MXU *is* the counter — no hash map, no scatter; the paper's
worst-case collision pathology disappears by construction.

Tiling follows ``kernels.dfg_count`` (which is now a thin square-case
wrapper over this kernel): the event stream is cut into ``block_e`` tiles
(grid axis k, the reduction axis — innermost, so each output block
accumulates in VMEM across iterations); the (S, D) count matrix is cut
into ``block_s x block_d`` output tiles (grid axes i, j).  Accumulation is
float32 on the MXU — exact for integer-valued weights while per-cell sums
stay < 2^24; the dispatch layer routes inexact-float weights to the XLA
scatter unless told otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(src_ref, dst_ref, w_ref, out_ref, *, block_s, block_d):
    i = pl.program_id(0)          # src tile
    j = pl.program_id(1)          # dst tile
    k = pl.program_id(2)          # event tile (reduction — innermost)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = src_ref[...].reshape(-1, 1)            # (block_e, 1)
    d = dst_ref[...].reshape(-1, 1)
    w = w_ref[...].reshape(-1, 1)
    be = s.shape[0]
    rows_s = jax.lax.broadcasted_iota(jnp.int32, (be, block_s), 1)
    rows_d = jax.lax.broadcasted_iota(jnp.int32, (be, block_d), 1)
    x = jnp.where(s == rows_s + i * block_s, w, 0.0)             # (be, S_i)
    y = jnp.where(d == rows_d + j * block_d, 1.0, 0.0)           # (be, D_j)
    out_ref[...] += jnp.dot(x.T, y, preferred_element_type=jnp.float32)


def _round_up(x: int, m: int) -> int:
    return max(m, ((x + m - 1) // m) * m)


@functools.partial(jax.jit, static_argnames=("num_src", "num_dst", "block_e",
                                             "block_s", "block_d", "interpret"))
def pair_count_pallas(src: jax.Array, dst: jax.Array, w: jax.Array,
                      num_src: int, num_dst: int, *,
                      block_e: int = 512, block_s: int = 128,
                      block_d: int = 128, interpret: bool = True) -> jax.Array:
    """(num_src, num_dst) float32 weighted pair counts (OOB dropped).

    Padding events carry w == 0; the caller masks invalid pairs the same way.
    """
    e = src.shape[0]
    if e == 0:
        return jnp.zeros((num_src, num_dst), jnp.float32)
    pad_e = (-e) % block_e
    s_pad, d_pad = _round_up(num_src, block_s), _round_up(num_dst, block_d)
    srcp = jnp.pad(src.astype(jnp.int32), (0, pad_e), constant_values=-1)
    dstp = jnp.pad(dst.astype(jnp.int32), (0, pad_e), constant_values=-1)
    wp = jnp.pad(w.astype(jnp.float32), (0, pad_e))
    ne = (e + pad_e) // block_e

    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, block_d=block_d),
        grid=(s_pad // block_s, d_pad // block_d, ne),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, j, k: (k,)),
            pl.BlockSpec((block_e,), lambda i, j, k: (k,)),
            pl.BlockSpec((block_e,), lambda i, j, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_s, block_d), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s_pad, d_pad), jnp.float32),
        interpret=interpret,
    )(srcp, dstp, wp)
    return out[:num_src, :num_dst]
