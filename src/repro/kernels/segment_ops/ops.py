"""Public entry points for the segmented primitives — backend dispatched.

The paper reduces every process-mining algorithm to a handful of columnar
dataframe operations (§5.3–5.4); these four primitives are that handful,
named, with two interchangeable lowerings each (see ``core.backend``):

=================  ====================================  ===================
primitive          paper operation (§5.3/5.4, Table 3)   lowerings
=================  ====================================  ===================
``segment_reduce`` group(D, case) + aggregate            xla scatter / pallas
``histogram``      counting ``c(e)`` after proj          xla scatter / pallas
``pair_count``     shift + mergstrv + count (DFG)        xla / matmul / pallas
``segmented_scan`` case-local fold (variants, EFG)       xla scan / pallas
=================  ====================================  ===================

Dispatch: an explicit ``impl=`` wins; otherwise ``core.backend.resolve()``.
One guardrail: float accumulation is order-sensitive, and the streaming
engine promises *bitwise* streaming == whole-log results.  The XLA scatter
accumulates in row order (chunking-invariant); the Pallas tilings do not.
Integer accumulation is exact under any order, so counting always takes the
fast path — but inexact-float weighted sums fall back to the XLA lowering
unless the caller passes ``assume_exact=True`` (asserting the values are
integer-valued, e.g. one-hot prefix counts) or forces an ``impl``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref as _ref
from .histogram import histogram_pallas
from .pair_count import pair_count_pallas
from .segment_reduce import segment_reduce_pallas
from .segmented_scan import (segmented_affine_pallas,
                             segmented_polyhash_pallas,
                             segmented_sum_scan_pallas)

reduce_identity = _ref.reduce_identity


def _backend():
    # deferred: core.backend's parent package imports core.dfg, which
    # imports this package — a module-level import here would re-enter
    # segment_ops mid-init and bind submodules in place of these functions
    from repro.core import backend

    return backend


def _inexact(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact)


def _interpret() -> bool:
    return _backend().interpret_mode()


def _resolve(impl: str | None, order_sensitive: bool, assume_exact: bool) -> str:
    if impl is not None:
        return impl
    resolved = _backend().resolve(None)
    if resolved == "pallas" and order_sensitive and not assume_exact:
        return "xla"
    return resolved


def segment_reduce(values: jax.Array, segment_ids: jax.Array,
                   num_segments: int, op: str = "sum", *,
                   impl: str | None = None, assume_exact: bool = False,
                   block_e: int = 512) -> jax.Array:
    """(num_segments,) ``op``-reduction of ``values`` grouped by sorted ids.

    ``segment_ids`` must be the sorted, consecutive ids produced by
    ``ops.segment_ids_sorted`` / ``engine.global_segments``; out-of-range
    ids (including -1) are dropped.  Empty segments hold the op identity.
    """
    was_bool = values.dtype == jnp.bool_
    vals = values.astype(jnp.int32) if was_bool else values
    chosen = _resolve(impl, op == "sum" and _inexact(vals), assume_exact)
    if chosen == "pallas":
        out = segment_reduce_pallas(vals, segment_ids, num_segments, op,
                                    block_e=block_e,
                                    interpret=_interpret())
    elif chosen == "xla":
        out = _ref.segment_reduce_ref(vals, segment_ids, num_segments, op)
    else:
        raise ValueError(f"unknown segment_reduce impl {chosen!r}")
    if was_bool and op in ("min", "max"):
        return out > 0
    return out


def histogram(values: jax.Array, num_bins: int,
              weights: jax.Array | None = None, *,
              into: jax.Array | None = None,
              impl: str | None = None, assume_exact: bool = False,
              block_e: int = 512, block_b: int = 128) -> jax.Array:
    """Weighted bincount of dictionary-encoded ``values`` (OOB dropped).

    ``weights=None`` counts occurrences (int32); bool/int weights produce
    int32 counts; float weights produce a float32 accumulation.  ``into``
    accumulates onto an existing (num_bins,) state — for float weights the
    XLA lowering scatters onto it in row order, which is what keeps chunked
    streaming bitwise identical to the whole-log pass.
    """
    if weights is None:
        w = jnp.ones(values.shape, jnp.int32)
    elif weights.dtype == jnp.bool_ or jnp.issubdtype(weights.dtype, jnp.integer):
        w = weights.astype(jnp.int32)
    else:
        w = weights.astype(jnp.float32)
    chosen = _resolve(impl, _inexact(w), assume_exact)
    if chosen == "pallas":
        # the VPU kernel accumulates in the weight dtype: int32 counting
        # stays exact at any magnitude (no float32 2^24 ceiling)
        out = histogram_pallas(values, w, num_bins,
                               block_e=block_e, block_b=block_b,
                               interpret=_interpret())
        return out if into is None else into + out
    if chosen == "xla":
        return _ref.histogram_ref(values, num_bins, w, into)
    raise ValueError(f"unknown histogram impl {chosen!r}")


def pair_count(src: jax.Array, dst: jax.Array, num_src: int,
               num_dst: int | None = None,
               weights: jax.Array | None = None, *,
               into: jax.Array | None = None,
               impl: str | None = None, assume_exact: bool = False,
               block_e: int = 512, block_s: int = 128,
               block_d: int = 128) -> jax.Array:
    """(num_src, num_dst) weighted (src, dst) pair counts (OOB dropped).

    The generalized DFG counter: ``impl`` may also name the XLA one-hot
    ``"matmul"`` lowering (MXU formulation without the Pallas runtime).
    ``into`` accumulates onto an existing state (row order on XLA — see
    ``histogram``).  The pallas/matmul lowerings accumulate in float32 on
    the MXU — exact while every *per-cell* sum stays < 2^24; for larger
    per-edge counts use the exact ``impl="xla"`` scatter.
    """
    num_dst = num_src if num_dst is None else num_dst
    if weights is None:
        w = jnp.ones(src.shape, jnp.int32)
    elif weights.dtype == jnp.bool_ or jnp.issubdtype(weights.dtype, jnp.integer):
        w = weights.astype(jnp.int32)
    else:
        w = weights.astype(jnp.float32)
    chosen = _resolve(impl, _inexact(w), assume_exact)
    if chosen == "pallas":
        out = pair_count_pallas(src, dst, w.astype(jnp.float32),
                                num_src, num_dst, block_e=block_e,
                                block_s=block_s, block_d=block_d,
                                interpret=_interpret()
                                ).astype(w.dtype)
        return out if into is None else into + out
    if chosen == "matmul":
        # the matmul lowering has its own tuned block size (2048), larger
        # than the Pallas event tile — don't forward block_e
        out = pair_count_matmul(src, dst, num_src, num_dst, weights=w)
        return out if into is None else into + out
    if chosen == "xla":
        return _ref.pair_count_ref(src, dst, w, num_src, num_dst, into)
    raise ValueError(f"unknown pair_count impl {chosen!r}")


def pair_count_matmul(src, dst, num_src, num_dst=None, weights=None, *,
                      block: int = 2048):
    """The XLA blockwise one-hot matmul lowering, callable directly."""
    num_dst = num_src if num_dst is None else num_dst
    w = jnp.ones(src.shape, jnp.int32) if weights is None else weights
    out = _ref.pair_count_matmul(src, dst, w.astype(jnp.float32),
                                 num_src, num_dst, block)
    if w.dtype != jnp.float32:
        return out.astype(jnp.int32)
    return out


def segmented_scan(values: jax.Array, seg_starts: jax.Array, carry,
                   op: str = "sum", *, base: int | None = None,
                   impl: str | None = None, assume_exact: bool = False,
                   block_e: int = 512):
    """Case-local inclusive scan; returns ``(ys, carry_out)``.

    ``op="sum"``: segmented prefix sum over (N,) or (N, K) rows, seeded by
    ``carry`` (the open segment's running total).  ``op="polyhash"``: the
    rolling hash ``h <- h*base + v`` (mod 2**32) over uint32 addends —
    exact, hence bitwise identical across lowerings.  ``carry_out`` is the
    inclusive value at the final row (feeds the next chunk's carry).
    """
    if op == "polyhash":
        if base is None:
            raise ValueError("segmented_scan(op='polyhash') requires base=")
        chosen = _resolve(impl, False, assume_exact)
        if chosen == "pallas":
            return segmented_polyhash_pallas(
                values, seg_starts, carry, int(base), block_e=block_e,
                interpret=_interpret())
        if chosen == "xla":
            return _ref.segmented_scan_ref(values, seg_starts, carry,
                                           "polyhash", base)
        raise ValueError(f"unknown segmented_scan impl {chosen!r}")
    if op == "sum":
        chosen = _resolve(impl, _inexact(values), assume_exact)
        if chosen == "pallas":
            return segmented_sum_scan_pallas(
                values, seg_starts, carry, block_e=block_e,
                interpret=_interpret())
        if chosen == "xla":
            return _ref.segmented_scan_ref(values, seg_starts, carry, "sum")
        raise ValueError(f"unknown segmented_scan impl {chosen!r}")
    raise ValueError(f"unknown segmented_scan op {op!r}")


def segmented_affine(mul: jax.Array, add: jax.Array, seg_starts: jax.Array,
                     carry, *, impl: str | None = None, block_e: int = 512):
    """Case-local scan of explicit affine maps ``h <- h*mul + add`` (mod
    2**32); returns ``(ys, carry_out)``.

    The generalization of ``segmented_scan(op="polyhash")`` where each row
    carries its own coefficients — what lets the variants kernel fold a
    pre-composed header *sketch* entry (the collapsed map of a whole skipped
    case run) in a single row.  uint32 arithmetic is exact mod 2^32, so both
    lowerings are bitwise identical.
    """
    chosen = _resolve(impl, False, False)
    if chosen == "pallas":
        return segmented_affine_pallas(mul, add, seg_starts, carry,
                                       block_e=block_e,
                                       interpret=_interpret())
    if chosen == "xla":
        return _ref.segmented_affine_ref(mul, add, seg_starts, carry)
    raise ValueError(f"unknown segmented_affine impl {chosen!r}")
