"""XLA reference lowerings for the segmented primitives.

These are the paper's direct columnar translations — scatter-adds and
``lax.scan`` folds — kept verbatim from the pre-primitive core modules.
They are the parity oracles for the Pallas kernels and the mandatory
lowering for order-sensitive float accumulations (XLA scatter applies
updates in row order, which is what makes streaming == whole-log bitwise
for non-integer float weights).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Reduction identities, shared with the Pallas kernels so both lowerings
# return bitwise-identical values for empty segments.
_F32_MAX = jnp.float32(jnp.finfo(jnp.float32).max)


def reduce_identity(op: str, dtype) -> jax.Array:
    dtype = jnp.dtype(dtype)
    if op == "sum":
        return jnp.zeros((), dtype)
    if op == "min":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(jnp.inf, dtype)
        return jnp.array(jnp.iinfo(dtype).max, dtype)
    if op == "max":
        if jnp.issubdtype(dtype, jnp.floating):
            return jnp.array(-jnp.inf, dtype)
        return jnp.array(jnp.iinfo(dtype).min, dtype)
    raise ValueError(f"unknown segment_reduce op {op!r}")


@functools.partial(jax.jit, static_argnames=("num_segments", "op"))
def segment_reduce_ref(values: jax.Array, segment_ids: jax.Array,
                       num_segments: int, op: str = "sum") -> jax.Array:
    """Scatter lowering with a scratch slot for out-of-range ids.

    ``.at[]`` wraps *negative* indices (only ids >= size are dropped), so
    out-of-range ids — including -1 — are first routed to a scratch slot
    that is sliced off, the pre-primitive core idiom.
    """
    s = num_segments
    ok = (segment_ids >= 0) & (segment_ids < s)
    idx = jnp.where(ok, segment_ids, s)
    init = jnp.full((s + 1,), reduce_identity(op, values.dtype))
    if op == "sum":
        return init.at[idx].add(values)[:-1]
    if op == "min":
        return init.at[idx].min(values)[:-1]
    return init.at[idx].max(values)[:-1]


@functools.partial(jax.jit, static_argnames=("num_bins",))
def histogram_ref(values: jax.Array, num_bins: int, weights: jax.Array,
                  into: jax.Array | None = None) -> jax.Array:
    """Weighted bincount; out-of-range values hit a scratch bin (sliced off).

    ``into`` scatters onto an existing accumulator *in row order* — for
    float weights this is what keeps a chunked stream bitwise identical to
    the whole-log pass (additions hit the running state left-to-right
    instead of being grouped per chunk).
    """
    ok = (values >= 0) & (values < num_bins)
    idx = jnp.where(ok, values, num_bins)
    init = jnp.zeros((num_bins,), weights.dtype) if into is None else into
    init = jnp.concatenate([init, jnp.zeros((1,), weights.dtype)])
    return init.at[idx].add(weights)[:-1]


@functools.partial(jax.jit, static_argnames=("num_src", "num_dst"))
def pair_count_ref(src: jax.Array, dst: jax.Array, w: jax.Array,
                   num_src: int, num_dst: int,
                   into: jax.Array | None = None) -> jax.Array:
    """Flat-key scatter-add: ``counts[src_i, dst_i] += w_i`` (OOB dropped).

    The paper's map-reduce strategy (§5.4 strategy 1): pair keys reduced
    via scatter-add, masked pairs routed to a scratch bucket.  ``into``
    accumulates onto an existing (num_src, num_dst) state in row order
    (see ``histogram_ref``).
    """
    ok = ((src >= 0) & (src < num_src)) & ((dst >= 0) & (dst < num_dst))
    key = jnp.where(ok, src.astype(jnp.int32) * num_dst + dst, num_src * num_dst)
    init = (jnp.zeros((num_src * num_dst,), w.dtype) if into is None
            else into.reshape(-1))
    flat = jnp.concatenate([init, jnp.zeros((1,), w.dtype)]).at[key].add(w)
    return flat[:-1].reshape(num_src, num_dst)


@functools.partial(jax.jit, static_argnames=("num_src", "num_dst", "block"))
def pair_count_matmul(src: jax.Array, dst: jax.Array, w: jax.Array,
                      num_src: int, num_dst: int, block: int = 2048) -> jax.Array:
    """Blockwise one-hot matmul: ``C = sum_k (onehot(src_k) * w_k)^T @ onehot(dst_k)``.

    The XLA twin of the Pallas MXU kernel (float32 accumulation; exact for
    integer-valued weights with per-cell sums < 2^24).
    """
    n = src.shape[0]
    pad = (-n) % block
    srcp = jnp.pad(src.astype(jnp.int32), (0, pad), constant_values=-1)
    dstp = jnp.pad(dst.astype(jnp.int32), (0, pad), constant_values=-1)
    wp = jnp.pad(w.astype(jnp.float32), (0, pad))
    nblk = (n + pad) // block

    def body(c, xs):
        s, d, ww = xs
        x = jax.nn.one_hot(s, num_src, dtype=jnp.float32) * ww[:, None]
        y = jax.nn.one_hot(d, num_dst, dtype=jnp.float32)
        return c + jnp.dot(x.T, y, preferred_element_type=jnp.float32), None

    c, _ = jax.lax.scan(
        body, jnp.zeros((num_src, num_dst), jnp.float32),
        (srcp.reshape(nblk, block), dstp.reshape(nblk, block),
         wp.reshape(nblk, block)))
    return c.astype(w.dtype)


@jax.jit
def segmented_affine_ref(mul: jax.Array, add: jax.Array,
                         seg_starts: jax.Array, carry):
    """Sequential fold of explicit affine maps ``h -> h*mul + add``
    (segment starts reset ``h`` to 0 first).  Returns ``(ys, carry_out)``."""

    def step(h, xs):
        m, b, start = xs
        h = jnp.where(start, jnp.zeros_like(h), h) * m + b
        return h, h

    last, ys = jax.lax.scan(step, carry, (mul, add, seg_starts))
    return ys, last


@functools.partial(jax.jit, static_argnames=("op",))
def segmented_scan_ref(values: jax.Array, seg_starts: jax.Array,
                       carry, op: str = "sum", base=None):
    """Sequential ``lax.scan`` fold (the pre-primitive core formulation).

    Returns ``(ys_inclusive, carry_out)``; ``carry_out`` is the inclusive
    value at the final row (the open segment's running state).
    """
    if op == "sum":
        zero = jnp.zeros_like(carry)

        def step(h, xs):
            v, start = xs
            h = jnp.where(start, zero, h) + v
            return h, h

        last, ys = jax.lax.scan(step, carry, (values, seg_starts))
        return ys, last
    if op == "polyhash":
        b = jnp.asarray(base, values.dtype)

        def step(h, xs):
            v, start = xs
            h = jnp.where(start, jnp.zeros_like(h), h) * b + v
            return h, h

        last, ys = jax.lax.scan(step, carry, (values, seg_starts))
        return ys, last
    raise ValueError(f"unknown segmented_scan op {op!r}")
