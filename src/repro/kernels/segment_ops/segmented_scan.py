"""Pallas kernel: case-local (segmented) scans over the sorted stream.

Two scan monoids cover every case-local cumulative op in the core:

* ``sum``      — segmented inclusive prefix sum over vector rows; the
  eventually-follows prefix vectors of §5.4-style LTL counting.
* ``polyhash`` — the rolling variant hash ``h <- h * base + v`` (mod 2^32).
  An affine map ``h -> h*m + b``; affine composition is associative, so the
  sequential fold becomes a parallel scan with *bitwise* identical output
  (uint32 arithmetic is exact mod 2^32).

Each tile runs a Hillis–Steele doubling scan on the VPU (log2(block) vector
steps) with the standard segmented-scan flag treatment: a row whose
accumulated flag is set ignores its predecessor.  The open segment's
running state crosses tiles through a carry block that lives in VMEM for
the whole sequential grid — the same one-row-halo idea as the streaming
engine, one level down.  Tail padding contributes the monoid identity, so
the carry emerging from the last tile is the true stream state.

Validated in interpret mode on CPU; the TPU lowering runs the same body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _positions(w: int) -> jax.Array:
    return jax.lax.broadcasted_iota(jnp.int32, (w, 1), 0).reshape(w)


def _polyhash_kernel(v_ref, f_ref, ok_ref, c0_ref, ys_ref, carry_ref, *, base):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = c0_ref[...]

    v = v_ref[...]                       # (W,) addends
    ok = ok_ref[...]                     # (W,) bool — False on tail padding
    w = v.shape[0]
    # each row is the affine map h -> h*m + b; padding is the identity map
    m = jnp.where(ok, jnp.full((w,), base, v.dtype), jnp.ones((w,), v.dtype))
    b = jnp.where(ok, v, jnp.zeros((w,), v.dtype))
    ff = f_ref[...] & ok
    idx = _positions(w)
    d = 1
    while d < w:                         # static unroll: log2(W) VPU steps
        pm = jnp.concatenate([jnp.ones((d,), m.dtype), m[:-d]])
        pb = jnp.concatenate([jnp.zeros((d,), b.dtype), b[:-d]])
        pf = jnp.concatenate([jnp.zeros((d,), jnp.bool_), ff[:-d]])
        take = (idx >= d) & ~ff
        b = jnp.where(take, pb * m + b, b)   # compose prev∘cur (uses old m)
        m = jnp.where(take, pm * m, m)
        ff = ff | (pf & (idx >= d))
        d *= 2
    h_in = carry_ref[0]
    ys = jnp.where(ff, b, h_in * m + b)
    ys_ref[...] = ys
    carry_ref[0] = ys[-1]


def _affine_kernel(m_ref, b_ref, f_ref, ok_ref, c0_ref, ys_ref, carry_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = c0_ref[...]

    ok = ok_ref[...]                     # (W,) bool — False on tail padding
    w = ok.shape[0]
    # generalized polyhash tile: each row carries an *explicit* affine map
    # h -> h*m + b (a header sketch entry, or base/value for a plain row);
    # padding is the identity map
    m = jnp.where(ok, m_ref[...], jnp.ones((w,), m_ref.dtype))
    b = jnp.where(ok, b_ref[...], jnp.zeros((w,), b_ref.dtype))
    ff = f_ref[...] & ok
    idx = _positions(w)
    d = 1
    while d < w:                         # static unroll: log2(W) VPU steps
        pm = jnp.concatenate([jnp.ones((d,), m.dtype), m[:-d]])
        pb = jnp.concatenate([jnp.zeros((d,), b.dtype), b[:-d]])
        pf = jnp.concatenate([jnp.zeros((d,), jnp.bool_), ff[:-d]])
        take = (idx >= d) & ~ff
        b = jnp.where(take, pb * m + b, b)   # compose prev∘cur (uses old m)
        m = jnp.where(take, pm * m, m)
        ff = ff | (pf & (idx >= d))
        d *= 2
    h_in = carry_ref[0]
    ys = jnp.where(ff, b, h_in * m + b)
    ys_ref[...] = ys
    carry_ref[0] = ys[-1]


def _segsum_kernel(v_ref, f_ref, c0_ref, ys_ref, carry_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = c0_ref[...]

    x = v_ref[...]                       # (W, K) — tail padding rows are 0
    ff = f_ref[...]                      # (W,) bool
    w, kdim = x.shape
    idx = _positions(w)
    d = 1
    while d < w:
        px = jnp.concatenate([jnp.zeros((d, kdim), x.dtype), x[:-d]], axis=0)
        pf = jnp.concatenate([jnp.zeros((d,), jnp.bool_), ff[:-d]])
        take = (idx >= d) & ~ff
        x = jnp.where(take.reshape(-1, 1), px + x, x)
        ff = ff | (pf & (idx >= d))
        d *= 2
    h_in = carry_ref[...]                # (K,)
    ys = jnp.where(ff.reshape(-1, 1), x, h_in.reshape(1, -1) + x)
    ys_ref[...] = ys
    carry_ref[...] = ys[-1]


@functools.partial(jax.jit, static_argnames=("base", "block_e", "interpret"))
def segmented_polyhash_pallas(values: jax.Array, seg_starts: jax.Array,
                              carry: jax.Array, base: int, *,
                              block_e: int = 512, interpret: bool = True):
    """Inclusive segmented rolling hash; returns ``(ys, carry_out)``."""
    n = values.shape[0]
    if n == 0:
        return values, carry
    pad = (-n) % block_e
    v = jnp.pad(values, (0, pad))
    f = jnp.pad(seg_starts.astype(bool), (0, pad))
    ok = jnp.pad(jnp.ones((n,), bool), (0, pad))
    ys, cout = pl.pallas_call(
        functools.partial(_polyhash_kernel, base=base),
        grid=((n + pad) // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), values.dtype),
            jax.ShapeDtypeStruct((1,), values.dtype),
        ],
        interpret=interpret,
    )(v, f, ok, jnp.reshape(carry, (1,)))
    return ys[:n], cout[0]


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def segmented_affine_pallas(mul: jax.Array, add: jax.Array,
                            seg_starts: jax.Array, carry: jax.Array, *,
                            block_e: int = 512, interpret: bool = True):
    """Inclusive segmented scan of explicit affine maps ``h -> h*mul + b``;
    returns ``(ys, carry_out)``.  The polyhash scan with per-row
    coefficients — uint32-exact, so bitwise across lowerings."""
    n = mul.shape[0]
    if n == 0:
        return mul, carry
    pad = (-n) % block_e
    m = jnp.pad(mul, (0, pad))
    b = jnp.pad(add, (0, pad))
    f = jnp.pad(seg_starts.astype(bool), (0, pad))
    ok = jnp.pad(jnp.ones((n,), bool), (0, pad))
    ys, cout = pl.pallas_call(
        _affine_kernel,
        grid=((n + pad) // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((1,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad,), mul.dtype),
            jax.ShapeDtypeStruct((1,), mul.dtype),
        ],
        interpret=interpret,
    )(m, b, f, ok, jnp.reshape(carry, (1,)))
    return ys[:n], cout[0]


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def segmented_sum_scan_pallas(values: jax.Array, seg_starts: jax.Array,
                              carry: jax.Array, *,
                              block_e: int = 512, interpret: bool = True):
    """Inclusive segmented prefix sum over rows; returns ``(ys, carry_out)``.

    ``values`` is (N, K) with carry (K,), or (N,) with a scalar carry.
    Exact (hence bitwise impl-independent) for integer-valued inputs.
    """
    squeeze = values.ndim == 1
    vals = values.reshape(values.shape[0], -1)
    c0 = jnp.reshape(carry, (vals.shape[1],))
    n, kdim = vals.shape
    if n == 0:
        return values, carry
    pad = (-n) % block_e
    v = jnp.pad(vals, ((0, pad), (0, 0)))
    f = jnp.pad(seg_starts.astype(bool), (0, pad))
    ys, cout = pl.pallas_call(
        _segsum_kernel,
        grid=((n + pad) // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, kdim), lambda t: (t, 0)),
            pl.BlockSpec((block_e,), lambda t: (t,)),
            pl.BlockSpec((kdim,), lambda t: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_e, kdim), lambda t: (t, 0)),
            pl.BlockSpec((kdim,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + pad, kdim), vals.dtype),
            jax.ShapeDtypeStruct((kdim,), vals.dtype),
        ],
        interpret=interpret,
    )(v, f, c0)
    ys = ys[:n]
    if squeeze:
        return ys.reshape(-1), cout[0]
    return ys, cout.reshape(jnp.shape(carry))
