"""Segmented columnar primitives — the paper's §5.3/5.4 operation set,
lowered twice (Pallas TPU kernels + XLA reference) behind one dispatch
(see ``repro.core.backend``)."""
from . import ops, ref
from .histogram import histogram_pallas
from .ops import (histogram, pair_count, pair_count_matmul, segment_reduce,
                  segmented_affine, segmented_scan)
from .pair_count import pair_count_pallas
from .ref import (histogram_ref, pair_count_ref, segment_reduce_ref,
                  segmented_affine_ref, segmented_scan_ref)
from .segment_reduce import segment_reduce_pallas
from .segmented_scan import (segmented_affine_pallas,
                             segmented_polyhash_pallas,
                             segmented_sum_scan_pallas)

__all__ = [
    "ops", "ref",
    "segment_reduce", "histogram", "pair_count", "pair_count_matmul",
    "segmented_scan", "segmented_affine",
    "segment_reduce_pallas", "histogram_pallas", "pair_count_pallas",
    "segmented_polyhash_pallas", "segmented_affine_pallas",
    "segmented_sum_scan_pallas",
    "segment_reduce_ref", "histogram_ref", "pair_count_ref",
    "segmented_scan_ref", "segmented_affine_ref",
]
