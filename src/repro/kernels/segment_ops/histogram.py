"""Pallas kernel: weighted bincount (the paper's ``c(e)`` counting, §5.4).

Values are *unsorted* dictionary-encoded ids; the count vector is tiled into
``block_b`` output windows (grid axis i) and the event stream into
``block_e`` tiles (grid axis k — innermost, so each output window
accumulates in VMEM across the whole stream):

    out[b] += sum over tile rows of where(v == b, w, 0)

A VPU masked reduction — no scatter, no atomic traffic.  Out-of-range
values are dropped (they match no bin).  Accumulation runs in the weight
dtype: int32 counting is exact at any magnitude; float32 weights are
tile-reduced (order differs from row-order scatter — the dispatch layer
routes inexact-float weights to the XLA lowering unless told otherwise).
Validated in interpret mode on CPU; the TPU lowering runs the same body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(val_ref, w_ref, out_ref, *, block_b):
    i = pl.program_id(0)          # bin window
    k = pl.program_id(1)          # event tile (reduction — innermost)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    v = val_ref[...].reshape(-1, 1)                      # (block_e, 1)
    w = w_ref[...].reshape(-1, 1)
    be = v.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (be, block_b), 1) + i * block_b
    out_ref[...] += jnp.where(v == bins, w, 0).sum(axis=0)


@functools.partial(jax.jit,
                   static_argnames=("num_bins", "block_e", "block_b", "interpret"))
def histogram_pallas(values: jax.Array, weights: jax.Array, num_bins: int, *,
                     block_e: int = 512, block_b: int = 128,
                     interpret: bool = True) -> jax.Array:
    """(num_bins,) weighted bincount of ``values`` (OOB dropped)."""
    n = values.shape[0]
    if n == 0:
        return jnp.zeros((num_bins,), weights.dtype)
    pad_e = (-n) % block_e
    val = jnp.pad(values.astype(jnp.int32), (0, pad_e), constant_values=-1)
    w = jnp.pad(weights, (0, pad_e))
    b_pad = max(block_b, ((num_bins + block_b - 1) // block_b) * block_b)
    ne, nb = (n + pad_e) // block_e, b_pad // block_b

    out = pl.pallas_call(
        functools.partial(_kernel, block_b=block_b),
        grid=(nb, ne),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i, k: (k,)),
            pl.BlockSpec((block_e,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((b_pad,), weights.dtype),
        interpret=interpret,
    )(val, w)
    return out[:num_bins]
