"""Fused multi-verb collection benchmark: one scan vs N scans, prefetch.

Three measurements over one synthetic log written as monthly partitions:

* **fused vs separate** — ``ds.collect_many(verbs)`` against the sum of
  the separate ``ds.collect(verb)`` calls, at several verb-set sizes and
  selectivities; records wall clock and bytes decoded, asserts per-verb
  bitwise parity everywhere and (smoke) that a fused 3+-verb collection
  decodes >= 2x fewer bytes than the separate runs;
* **prefetch sweep** — the fused streaming collection at read-ahead
  depths 0 / 1 / 2 (``REPRO_QUERY_PREFETCH``): what overlapping decode
  with kernel time buys, with identical bytes and results by design
  (on a shared-core CPU host producer and consumer compete for the same
  cores, so expect roughly neutral wall clock there; the overlap is for
  accelerator targets where host decode hides behind device compute);
* **dashboard profile** — ``ds.profile()``: every registered verb in
  one pass (the ``examples/dashboard.py`` workload).

Writes the ``BENCH_fusion.json`` trajectory artifact.

Standalone:  python benchmarks/bench_fusion.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only fusion
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np

# verb sets of growing width; every member is mask_exact so the fused
# scan stays pruned (variants is benchmarked separately in bench_query)
VERB_SETS = (
    ("dfg", "stats"),
    ("dfg", "stats", "performance_dfg"),
    ("dfg", "stats", "performance_dfg", "alpha", "heuristics"),
)
SELECTIVITIES = (0.10, 1.0)


def _tree_equal(a, b):
    import dataclasses

    import jax

    if isinstance(a, (jax.Array, np.ndarray)):
        return bool((np.asarray(a) == np.asarray(b)).all())
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        return type(a) is type(b) and all(
            _tree_equal(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, dict):
        return set(a) == set(b) and all(_tree_equal(a[k], b[k]) for k in a)
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(
            _tree_equal(x, y) for x, y in zip(a, b))
    return a == b


def run(num_cases: int = 50_000, num_activities: int = 12, seed: int = 31,
        num_files: int = 4, groups_per_file: int = 8,
        out_json: str | None = "BENCH_fusion.json", smoke: bool = False):
    import jax
    import jax.numpy as jnp

    import repro
    from repro.core import CASE
    from repro.data import synthetic
    from repro.query import col
    from repro.storage import edf

    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=num_activities,
                                       seed=seed)
    n = frame.nrows
    emit("fusion/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")

    d = tempfile.mkdtemp()
    case = np.asarray(frame[CASE])
    paths = []
    per = -(-num_cases // num_files)
    for m in range(num_files):
        lo = int(np.searchsorted(case, m * per))
        hi = int(np.searchsorted(case, (m + 1) * per))
        if lo == hi:
            continue
        p = os.path.join(d, f"month_{m:02d}.edf")
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables, codec="zlib1",
                  row_group_rows=max(1, (hi - lo) // groups_per_file))
        paths.append(p)
    total_bytes = sum(os.path.getsize(p) for p in paths)
    emit("fusion/write_partitions", 0.0,
         f"files={len(paths)};bytes={total_bytes}")

    base = repro.open(paths)

    # ------------------------------------------------ fused vs separate
    points = []
    for sel in SELECTIVITIES:
        hi = max(0, int(num_cases * sel) - 1)
        ds = base.filter(col(CASE).between(0, hi))
        for verbs in VERB_SETS:
            fused = ds.collect_many(verbs, engine="streaming")
            us_fused = timeit(
                lambda: ds.collect_many(verbs, engine="streaming"))
            sep, sep_bytes, us_sep = {}, 0, 0.0
            for v in verbs:
                r = ds.collect(v, engine="streaming")
                sep[v] = r.result
                sep_bytes += r.report.bytes_read
                us_sep += timeit(
                    lambda v=v: ds.collect(v, engine="streaming"))
            for v in verbs:
                assert _tree_equal(fused[v], sep[v]), \
                    f"fused != separate at sel={sel}:{v}"
            point = {
                "selectivity": sel,
                "verbs": list(verbs),
                "bytes_fused": fused.report.bytes_read,
                "bytes_separate": sep_bytes,
                "bytes_ratio": sep_bytes / max(fused.report.bytes_read, 1),
                "us_fused": us_fused * 1e6,
                "us_separate": us_sep * 1e6,
                "speedup": us_sep / max(us_fused, 1e-9),
            }
            points.append(point)
            emit(f"fusion/sel={sel}_verbs={len(verbs)}", us_fused,
                 f"sep_us={us_sep*1e6:.0f};"
                 f"bytes={point['bytes_fused']}/{point['bytes_separate']};"
                 f"speedup={point['speedup']:.2f}x")

    # the acceptance gate: sharing one scan across 3+ verbs must cut the
    # bytes decoded at least in half vs running the scans separately
    wide = [p for p in points if len(p["verbs"]) >= 3]
    best_ratio = max(p["bytes_ratio"] for p in wide)
    assert best_ratio > 1.0, "fusion never saved a byte"
    if smoke:
        for p in wide:
            assert p["bytes_ratio"] >= 2.0, \
                (f"fused {p['verbs']} decoded only "
                 f"{p['bytes_ratio']:.2f}x fewer bytes (want >=2x)")

    # ------------------------------------------------ prefetch sweep
    verbs = VERB_SETS[-1]
    prefetch, ref = [], None
    for depth in (0, 1, 2):
        r = base.collect_many(verbs, engine="streaming", prefetch=depth)
        us = timeit(lambda: base.collect_many(verbs, engine="streaming",
                                              prefetch=depth))
        assert r.report.prefetch == depth
        if ref is None:
            ref = r.results
        else:
            for v in verbs:
                assert _tree_equal(r[v], ref[v]), \
                    f"prefetch={depth} changed {v}"
        prefetch.append({"depth": depth, "us": us * 1e6,
                         "bytes_read": r.report.bytes_read})
        emit(f"fusion/prefetch={depth}", us,
             f"bytes={r.report.bytes_read}")
    assert len({p["bytes_read"] for p in prefetch}) == 1, \
        "prefetch depth changed the bytes read"

    # ------------------------------------------------ dashboard profile
    us_profile = timeit(lambda: base.profile(engine="streaming"))
    nverbs = len(base.profile(engine="streaming").verbs)
    emit("fusion/profile_all_verbs", us_profile, f"verbs={nverbs}")

    if out_json:
        artifact = {
            "bench": "fusion",
            "jax": jax.__version__,
            "python": platform.python_version(),
            "backend": jax.default_backend(),
            "config": {"num_cases": num_cases,
                       "num_activities": num_activities, "events": n,
                       "files": len(paths), "bytes_total": total_bytes},
            "fused_vs_separate": points,
            "max_bytes_ratio": best_ratio,
            "prefetch_sweep": prefetch,
            "us_profile_all_verbs": us_profile * 1e6,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"fusion/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return points


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; asserts >=2x bytes saved + parity")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_fusion.json")
    args = ap.parse_args()
    header()
    cases = 200_000 if args.full else (15_000 if args.smoke else 50_000)
    points = run(num_cases=cases, out_json=args.out, smoke=args.smoke)
    if args.smoke:
        wide = [p for p in points if len(p["verbs"]) >= 3]
        print(f"fusion/SMOKE_OK,0.0,min_bytes_ratio="
              f"{min(p['bytes_ratio'] for p in wide):.2f}", flush=True)


if __name__ == "__main__":
    main()
