"""Paper Table 6: synthetic big logs L1..L5 (10^6..5x10^6 cases, ~7 ev/case).

Default scale runs L_k with k*10^5 cases to stay CI-friendly; --full in
run.py restores the paper's k*10^6. Reported: generation, disk size, load,
filter, DFG (shift-and-count on device) wall times."""
from __future__ import annotations

import os
import tempfile
import time

import jax

from repro.core import dfg
from repro.core.eventframe import ACTIVITY, CASE
from repro.core import filtering, ops
from repro.data import synthetic
from repro.storage import edf

from .common import emit, timeit


def run(scale=0.1, levels=(1, 2, 3, 4, 5)):
    for lvl in levels:
        n_cases = int(lvl * 1_000_000 * scale)
        t0 = time.perf_counter()
        frame, tables = synthetic.generate(num_cases=n_cases, num_activities=26,
                                           seed=lvl, extra_numeric_attrs=0)
        gen_t = time.perf_counter() - t0
        n = frame.nrows
        emit(f"table6/L{lvl}/generate", gen_t, f"cases={n_cases};events={n}")
        d = tempfile.mkdtemp()
        p = os.path.join(d, f"L{lvl}.edf")
        edf.write(p, frame, tables, codec="zlib1")
        emit(f"table6/L{lvl}/size", 0.0, f"bytes={os.path.getsize(p)}")
        t = timeit(lambda: edf.read(p, columns=[CASE, ACTIVITY]), repeat=1)
        emit(f"table6/L{lvl}/load_2col", t, f"events_per_s={n/t:.0f}")
        top = filtering.most_common_activity(frame, 26)
        t = timeit(lambda: jax.block_until_ready(
            ops.proj(frame, filtering.isin_mask(
                frame[ACTIVITY], top[None])).rows_valid().sum()))
        emit(f"table6/L{lvl}/filter", t, f"events_per_s={n/t:.0f}")
        t = timeit(lambda: jax.block_until_ready(dfg(frame, 26, method='shift').counts))
        emit(f"table6/L{lvl}/dfg", t, f"events_per_s={n/t:.0f}")
        del frame
