"""Paper Table 1: loading time + in-memory footprint, row vs columnar.

The paper measures XESLite-in-ProM; our row baseline is the JSONL classic
log (attr maps), the columnar path is EDF -> EventFrame. 'RAM' is the sum of
materialized array/object sizes (tracemalloc for the row path).
"""
from __future__ import annotations

import os
import sys
import tempfile
import tracemalloc

import numpy as np

from repro.core import ClassicEventLog
from repro.core.eventframe import ACTIVITY, CASE
from repro.data import synthetic
from repro.storage import edf, rowlog

from .common import emit, timeit


def frame_nbytes(frame):
    return sum(np.asarray(v).nbytes for v in frame.columns.values())


def run(num_cases=50_000):
    frame, tables = synthetic.generate(num_cases=num_cases, num_activities=26,
                                       seed=0, extra_numeric_attrs=3)
    n = frame.nrows
    d = tempfile.mkdtemp()
    pe = os.path.join(d, "log.edf")
    pr = os.path.join(d, "log.jsonl")
    edf.write(pe, frame, tables, codec="zlib1")
    log = ClassicEventLog.from_eventframe(frame, tables)
    rowlog.write(pr, log)

    t = timeit(lambda: edf.read(pe), repeat=3)
    emit("table1/load_columnar_all", t, f"events={n};MBps={os.path.getsize(pe)/t/1e6:.0f}")
    t2 = timeit(lambda: edf.read(pe, columns=[CASE, ACTIVITY]), repeat=3)
    emit("table1/load_columnar_2col", t2, f"speedup_vs_all={t/t2:.2f}x")
    t3 = timeit(lambda: rowlog.read(pr), repeat=1, warmup=0)
    emit("table1/load_row_jsonl", t3, f"slowdown_vs_columnar={t3/t:.1f}x")

    emit("table1/ram_columnar", 0.0, f"bytes={frame_nbytes(frame)}")
    tracemalloc.start()
    log2 = rowlog.read(pr)
    cur, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    emit("table1/ram_row_objects", 0.0,
         f"bytes={cur};ratio_vs_columnar={cur/max(frame_nbytes(frame),1):.1f}x")
