"""Dataset-facade benchmark: multi-log pruning, union overhead, dispatch.

Three measurements over one synthetic log written both as a single EDF
file and as N monthly partitions:

* **selectivity sweep** — a case-band filter over the multi-file dataset
  at decreasing selectivity, engine=streaming vs engine=eager; asserts
  streaming == eager bitwise at every point and (smoke) that a selective
  multi-log query reads < 20% of the dataset's bytes;
* **1-vs-N overhead** — the same unselective whole-log mine over one file
  vs N files (the cost of per-file compile + stream chaining);
* **dispatch crossover** — what engine="auto" picks across the sweep and
  how its latency compares to the best of eager/streaming (the cost
  model's regret).

Writes the ``BENCH_dataset.json`` trajectory artifact.

Standalone:  python benchmarks/bench_dataset.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only dataset
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np

SELECTIVITIES = (0.02, 0.10, 0.30, 1.0)


def run(num_cases: int = 50_000, num_activities: int = 12, seed: int = 23,
        num_files: int = 6, groups_per_file: int = 8,
        out_json: str | None = "BENCH_dataset.json", smoke: bool = False):
    import jax
    import jax.numpy as jnp

    import repro
    from repro.core import CASE
    from repro.data import synthetic
    from repro.query import col
    from repro.storage import edf

    a = num_activities
    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=a, seed=seed)
    n = frame.nrows
    emit("dataset/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")

    d = tempfile.mkdtemp()
    case = np.asarray(frame[CASE])
    # one file vs N monthly partitions of the same sorted log
    single = os.path.join(d, "whole.edf")
    edf.write(single, frame, tables, codec="zlib1",
              row_group_rows=max(1, n // (num_files * groups_per_file)))
    paths = []
    per = -(-num_cases // num_files)
    for m in range(num_files):
        lo = int(np.searchsorted(case, m * per))
        hi = int(np.searchsorted(case, (m + 1) * per))
        if lo == hi:
            continue
        p = os.path.join(d, f"month_{m:02d}.edf")
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables, codec="zlib1",
                  row_group_rows=max(1, (hi - lo) // groups_per_file))
        paths.append(p)
    total_bytes = sum(os.path.getsize(p) for p in paths)
    emit("dataset/write_partitions", 0.0,
         f"files={len(paths)};bytes={total_bytes}")

    ds = repro.open(paths)

    # ------------------------------------------------- selectivity sweep
    sweep = []
    for sel in SELECTIVITIES:
        hi = max(0, int(num_cases * sel) - 1)
        flt = ds.filter(col(CASE).between(0, hi))
        r_stream = flt.collect("dfg", engine="streaming")
        us_stream = timeit(lambda: flt.collect("dfg", engine="streaming"))
        r_eager = flt.collect("dfg", engine="eager")
        us_eager = timeit(lambda: flt.collect("dfg", engine="eager"))
        r_auto = flt.collect("dfg")
        us_auto = timeit(lambda: flt.collect("dfg"))
        for nm in ("counts", "starts", "ends"):
            got = np.asarray(getattr(r_stream.result, nm))
            ref = np.asarray(getattr(r_eager.result, nm))
            assert (got == ref).all(), f"streaming != eager at sel={sel}:{nm}"
        rep = r_stream.report
        point = {
            "selectivity": sel,
            "groups_total": rep.groups_total,
            "groups_skipped": rep.groups_skipped,
            "bytes_read": rep.bytes_read,
            "bytes_total": rep.bytes_total,
            "read_fraction": rep.bytes_read / max(rep.bytes_total, 1),
            "us_streaming": us_stream * 1e6,
            "us_eager": us_eager * 1e6,
            "us_auto": us_auto * 1e6,
            "auto_engine": r_auto.engine,
            "auto_regret": us_auto / max(min(us_stream, us_eager), 1e-9),
        }
        sweep.append(point)
        emit(f"dataset/sweep_sel={sel}", us_stream,
             f"read={rep.bytes_read}/{rep.bytes_total};"
             f"auto={r_auto.engine};eager_us={us_eager*1e6:.0f}")

    # a selective multi-log query must beat a full read at every size;
    # the hard < 20%-of-bytes acceptance gate is the smoke configuration
    # (fixed sizes — a full-scale run may shape groups differently)
    best = min(p["read_fraction"] for p in sweep)
    assert best < 1.0, "pruning never skipped a byte on a selective query"
    if smoke:
        assert best < 0.20, \
            f"selective multi-log query read {best:.1%} of bytes (want <20%)"

    # ------------------------------------------------- 1-vs-N overhead
    one = repro.open(single)
    us_one = timeit(lambda: one.collect("dfg", engine="streaming"))
    us_many = timeit(lambda: ds.collect("dfg", engine="streaming"))
    r1 = one.collect("dfg", engine="streaming").result
    rN = ds.collect("dfg", engine="streaming").result
    for nm in ("counts", "starts", "ends"):
        assert (np.asarray(getattr(r1, nm))
                == np.asarray(getattr(rN, nm))).all(), f"1 vs N file:{nm}"
    emit("dataset/one_file_full_mine", us_one, f"files=1")
    emit("dataset/n_file_full_mine", us_many,
         f"files={len(paths)};overhead={us_many/max(us_one,1e-9):.2f}x")

    # ------------------------------------------------- dispatch crossover
    crossover = None
    for p in sweep:
        want = "streaming" if p["us_streaming"] <= p["us_eager"] else "eager"
        if crossover is None and want == "eager":
            crossover = p["selectivity"]
        emit(f"dataset/dispatch_sel={p['selectivity']}", p["us_auto"] / 1e6,
             f"auto={p['auto_engine']};best={want};"
             f"regret={p['auto_regret']:.2f}x")

    if out_json:
        artifact = {
            "bench": "dataset",
            "jax": jax.__version__,
            "python": platform.python_version(),
            "backend": jax.default_backend(),
            "config": {"num_cases": num_cases, "num_activities": a,
                       "events": n, "files": len(paths),
                       "bytes_total": total_bytes},
            "sweep": sweep,
            "min_read_fraction": best,
            "one_vs_n": {"us_one_file": us_one * 1e6,
                         "us_n_files": us_many * 1e6,
                         "overhead": us_many / max(us_one, 1e-9)},
            "eager_streaming_crossover_selectivity": crossover,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"dataset/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; asserts <20%% bytes read + parity")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_dataset.json")
    args = ap.parse_args()
    header()
    cases = 200_000 if args.full else (15_000 if args.smoke else 50_000)
    sweep = run(num_cases=cases, out_json=args.out, smoke=args.smoke)
    if args.smoke:
        print(f"dataset/SMOKE_OK,0.0,min_read_fraction="
              f"{min(p['read_fraction'] for p in sweep):.3f}", flush=True)


if __name__ == "__main__":
    main()
