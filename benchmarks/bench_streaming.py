"""Out-of-core streaming vs whole-log mining (the paper's Table-6 scenario).

Generates a synthetic big log, writes it as an EDFV0002 file whose row
groups are a fixed chunk budget >= 10x smaller than the log, then mines
DFG + stats + variants + performance-DFG in ONE streaming pass over the row
groups (``core.engine.compose``) with peak residency of a single chunk's
columns (+ an O(1) carry). Results are asserted bitwise-identical to the
whole-log jitted path, and per-chunk resident bytes are accounted to
demonstrate the memory bound.

Standalone:  python benchmarks/bench_streaming.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only streaming
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode: python benchmarks/bench_streaming.py
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np


def _frame_nbytes(frame) -> int:
    total = sum(np.asarray(v).nbytes for v in frame.columns.values())
    total += sum(np.asarray(v).nbytes for v in frame.valid.values())
    if frame.row_valid is not None:
        total += np.asarray(frame.row_valid).nbytes
    return total


class _Metered:
    """Wrap a chunk source; record chunk count and peak resident bytes."""

    def __init__(self, source):
        self.source = source
        self.chunks = 0
        self.peak_bytes = 0

    def __iter__(self):
        for chunk in self.source:
            self.chunks += 1
            self.peak_bytes = max(self.peak_bytes, _frame_nbytes(chunk))
            yield chunk


def run(num_cases: int = 500_000, num_activities: int = 26, seed: int = 6,
        min_chunks: int = 12, assert_equal: bool = True):
    import jax
    from repro.core import ChunkedEventFrame, engine, stats, variants
    from repro.core.dfg import dfg_kernel, dfg_segment
    from repro.core.eventframe import ACTIVITY, CASE, TIMESTAMP
    from repro.core.performance import performance_dfg, performance_dfg_kernel
    from repro.data import synthetic
    from repro.storage import edf

    a = num_activities
    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases, num_activities=a,
                                       seed=seed, extra_numeric_attrs=1)
    n = frame.nrows
    emit("streaming/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")

    # chunk budget: the log must be >= 10x one chunk (the Table-6 claim)
    chunk_rows = max(1, n // min_chunks)
    assert n >= 10 * chunk_rows, (n, chunk_rows)
    d = tempfile.mkdtemp()
    path = os.path.join(d, "stream.edf")
    t0 = time.perf_counter()
    edf.write(path, frame, tables, codec="zlib1", row_group_rows=chunk_rows)
    emit("streaming/write_edf_v2", time.perf_counter() - t0,
         f"bytes={os.path.getsize(path)};groups={edf.num_row_groups(path)}")
    n_groups = edf.num_row_groups(path)
    assert n_groups >= 8, n_groups

    num_cases_cap = num_cases + 1
    make_kernel = lambda: engine.compose({
        "dfg": dfg_kernel(a),
        "acts": stats.activity_counts_kernel(a),
        "sizes": stats.case_sizes_kernel(num_cases_cap),
        "durations": stats.case_durations_kernel(num_cases_cap),
        "variants": variants.variants_kernel(num_cases_cap),
        "perf": performance_dfg_kernel(a),
    })

    # ---- streaming pass: disk -> device, one row group resident at a time
    want = [CASE, ACTIVITY, TIMESTAMP]
    meter = _Metered(ChunkedEventFrame.from_edf(path, columns=want))
    t0 = time.perf_counter()
    out = engine.run_streaming(make_kernel(), meter)
    jax.block_until_ready(out["dfg"].counts)
    t_stream = time.perf_counter() - t0
    emit("streaming/mine_streamed", t_stream,
         f"events_per_s={n / t_stream:.0f};chunks={meter.chunks}")
    emit("streaming/peak_resident", 0.0,
         f"chunk_bytes={meter.peak_bytes};whole_bytes={_frame_nbytes(frame)}"
         f";ratio={_frame_nbytes(frame) / max(meter.peak_bytes, 1):.1f}")

    # ---- whole-log reference (the single-chunk special case)
    proj = frame.select(want)
    t_whole = timeit(lambda: jax.block_until_ready(dfg_segment(proj, a).counts))
    emit("streaming/mine_whole_log_dfg", t_whole, f"events_per_s={n / t_whole:.0f}")

    if assert_equal:
        ref_dfg = dfg_segment(proj, a)
        for nm in ("counts", "starts", "ends"):
            assert (np.asarray(getattr(out["dfg"], nm))
                    == np.asarray(getattr(ref_dfg, nm))).all(), nm
        assert (np.asarray(out["acts"])
                == np.asarray(stats.activity_counts(proj, a))).all()
        assert (np.asarray(out["sizes"])
                == np.asarray(stats.case_sizes(proj, num_cases_cap))).all()
        np.testing.assert_array_equal(
            np.asarray(out["durations"]),
            np.asarray(stats.case_durations(proj, num_cases_cap)))
        fp1, fp2, ncases = out["variants"]
        wfp1, wfp2, _seg = variants.variant_fingerprints(proj)
        assert int(ncases) == num_cases
        assert (np.asarray(fp1)[:num_cases] == np.asarray(wfp1)[:num_cases]).all()
        assert (np.asarray(fp2)[:num_cases] == np.asarray(wfp2)[:num_cases]).all()
        pc, pm = out["perf"]
        rc, rm = performance_dfg(proj, a)
        assert (np.asarray(pc) == np.asarray(rc)).all()
        np.testing.assert_array_equal(np.asarray(pm), np.asarray(rm))
        emit("streaming/bitwise_equal", 0.0, "dfg+stats+variants+perf=identical")

    os.unlink(path)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (~2*10^5 events)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale Table-6 run (10^7+ events)")
    ap.add_argument("--cases", type=int, default=None)
    args = ap.parse_args(argv)
    if args.cases:
        cases = args.cases
    elif args.full:
        cases = 2_000_000
    elif args.smoke:
        cases = 30_000
    else:
        cases = 500_000
    header()
    run(num_cases=cases)


if __name__ == "__main__":
    main()
