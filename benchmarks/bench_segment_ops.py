"""Primitive-level timings for the segmented-primitive layer.

Times every ``kernels.segment_ops`` primitive on both lowerings — the XLA
scatter/scan reference and the Pallas kernel in interpret mode (CPU
correctness cost; TPU throughput comes from the roofline) — and writes the
``BENCH_segment_ops.json`` trajectory artifact so future PRs diff against a
stable perf baseline.

  PYTHONPATH=src python -m benchmarks.bench_segment_ops [--full] [--out F]
"""
from __future__ import annotations

import json
import platform

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import segment_ops as so

from .common import emit, timeit

IMPLS = ("xla", "pallas")


def _rows(n: int, nbins: int, rng):
    seg = np.sort(rng.integers(0, max(n // 8, 2), n)).astype(np.int32)
    seg = (np.cumsum(np.concatenate([[1], np.diff(seg) != 0])) - 1).astype(np.int32)
    return {
        "seg": jnp.asarray(seg),
        "nseg": int(seg.max()) + 1,
        "vals": jnp.asarray(rng.integers(0, 100, n), jnp.int32),
        "bins": jnp.asarray(rng.integers(0, nbins, n), jnp.int32),
        "w": jnp.asarray(rng.integers(0, 3, n), jnp.int32),
        "src": jnp.asarray(rng.integers(0, nbins, n), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, nbins, n), jnp.int32),
        "mask": jnp.asarray(rng.random(n) < 0.8),
        "acts": jnp.asarray(rng.integers(1, 27, n), jnp.uint32),
        "starts": jnp.asarray(np.asarray(rng.random(n) < 0.15)),
        "oh": jnp.asarray(np.eye(nbins, dtype=np.float32)[rng.integers(0, nbins, n)]),
    }


def run(full: bool = False, out_json: str | None = "BENCH_segment_ops.json"):
    n = 200_000 if full else 20_000
    nbins = 26
    rng = np.random.default_rng(17)
    d = _rows(n, nbins, rng)
    results = {}

    def record(name, impl, fn, repeat=3):
        t = timeit(fn, repeat=repeat)
        emit(f"segment_ops/{name}_{impl}", t, f"events_per_s={n/t:.0f}")
        results.setdefault(name, {})[impl] = {"us_per_call": t * 1e6,
                                              "events_per_s": n / t}

    for impl in IMPLS:
        # pallas-interpret is a correctness mode: time one call, not best-of
        rep = 3 if impl == "xla" else 1
        record("segment_reduce_sum", impl, lambda: jax.block_until_ready(
            so.segment_reduce(d["vals"], d["seg"], d["nseg"], "sum", impl=impl)), rep)
        record("segment_reduce_max", impl, lambda: jax.block_until_ready(
            so.segment_reduce(d["vals"], d["seg"], d["nseg"], "max", impl=impl)), rep)
        record("histogram", impl, lambda: jax.block_until_ready(
            so.histogram(d["bins"], nbins, d["w"], impl=impl)), rep)
        record("pair_count", impl, lambda: jax.block_until_ready(
            so.pair_count(d["src"], d["dst"], nbins, weights=d["mask"], impl=impl)), rep)
        record("segmented_scan_polyhash", impl, lambda: jax.block_until_ready(
            so.segmented_scan(d["acts"], d["starts"], jnp.uint32(0),
                              "polyhash", base=1_000_003, impl=impl)[0]), rep)
        record("segmented_scan_sum", impl, lambda: jax.block_until_ready(
            so.segmented_scan(d["oh"], d["starts"],
                              jnp.zeros((nbins,), jnp.float32), "sum",
                              impl=impl, assume_exact=True)[0]), rep)
    record("pair_count", "matmul", lambda: jax.block_until_ready(
        so.pair_count(d["src"], d["dst"], nbins, weights=d["mask"],
                      impl="matmul")))

    if out_json:
        artifact = {
            "bench": "segment_ops",
            "n_events": n,
            "num_bins": nbins,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "note": ("pallas timings are interpret-mode (CPU correctness "
                     "cost, not TPU throughput); xla is the compiled "
                     "scatter/scan reference"),
            "primitives": results,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"segment_ops/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_segment_ops.json")
    args = ap.parse_args()
    from .common import header

    header()
    run(full=args.full, out_json=args.out)
