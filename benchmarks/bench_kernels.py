"""Kernel micro-benchmarks (CPU: oracle + interpret-mode correctness cost;
the TPU numbers come from the dry-run roofline, benchmarks here give the
algorithmic comparison the paper's Table 4 implies).

  PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke]

``--smoke`` shrinks sizes and skips the attention comparison — the cheap
regression gate ``benchmarks.run`` uses by default (non ``--full``).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dfg
from repro.data import synthetic
from repro.kernels.dfg_count import dfg_count_pallas, dfg_count_ref

from .common import emit, timeit


def run(smoke: bool = False):
    cases = 10_000 if smoke else 100_000
    frame, tables = synthetic.generate(num_cases=cases, num_activities=26, seed=3)
    n = frame.nrows
    for method in ("shift", "segment", "matmul"):
        t = timeit(lambda: jax.block_until_ready(
            dfg(frame, 26, method=method).counts))
        emit(f"kernels/dfg_{method}", t, f"events_per_s={n/t:.0f}")

    rng = np.random.default_rng(0)
    e, a = (10_000 if smoke else 100_000), 128
    src = jnp.asarray(rng.integers(0, a, e), jnp.int32)
    dst = jnp.asarray(rng.integers(0, a, e), jnp.int32)
    w = jnp.ones((e,), jnp.float32)
    t = timeit(lambda: jax.block_until_ready(dfg_count_ref(src, dst, w, a)))
    emit("kernels/dfg_count_ref_scatter", t, f"events_per_s={e/t:.0f}")
    t = timeit(lambda: jax.block_until_ready(
        dfg_count_pallas(src, dst, w, a, interpret=True)), repeat=1)
    emit("kernels/dfg_count_pallas_interpret", t,
         "correctness-mode;TPU_perf=see_roofline")
    if smoke:
        return

    from repro.models.attention import attention_chunked, attention_ref
    q = jnp.asarray(rng.standard_normal((1, 512, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 512, 2, 64)), jnp.float32)
    fr = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    fc = jax.jit(lambda q, k, v: attention_chunked(q, k, v, chunk=128))
    t = timeit(lambda: jax.block_until_ready(fr(q, k, v)))
    emit("kernels/attention_ref_512", t, "materialized S^2")
    t2 = timeit(lambda: jax.block_until_ready(fc(q, k, v)))
    emit("kernels/attention_chunked_512", t2, f"vs_ref={t2/t:.2f}x")


if __name__ == "__main__":
    import argparse

    from .common import header

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes, skip attention comparison")
    args = ap.parse_args()
    header()
    run(smoke=args.smoke)
