"""Serving benchmark: concurrent query latency over a live-ingested log.

The mining service answers every request from a snapshot-consistent view
while an ingestor appends row groups underneath it.  This bench measures
the cost of that concurrency:

1. *static sweep* — client-thread counts (1/2/4) hammering ``collect``
   over a frozen partition set: per-request p50/p99 and aggregate QPS;
2. *live phase* — the same clients while an ingest thread appends the
   second half of the log batch by batch: p50/p99 under contention plus
   the service's optimistic-retry count;
3. *append delta* — one more batch lands, then a re-collect: because the
   service pins kernel capacity dims, the state cache must answer the old
   groups (``groups_cached`` > 0, cache hits advance) and only the fresh
   groups are decoded;
4. *HTTP round* — the same queries through the JSON API, measuring the
   serialization + transport overhead on top of the facade.

``--smoke`` asserts the acceptance gates: the live phase sustains
concurrent queries (every client result bitwise equal to re-mining its
claimed snapshot), and the post-append re-collect hits the warm state
cache.  Writes the ``BENCH_serving.json`` trajectory artifact.

Standalone:  python benchmarks/bench_serving.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import threading
import time
import urllib.request

if __package__ in (None, ""):  # script mode
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header
else:
    from .common import emit, header

import numpy as np

THREAD_SWEEP = (1, 2, 4)
VERBS = ("dfg", "activity_counts", "case_sizes")


def _percentiles(times: list[float]) -> dict:
    arr = np.asarray(times) * 1e6
    return {"requests": len(times),
            "p50_us": float(np.percentile(arr, 50)),
            "p99_us": float(np.percentile(arr, 99)),
            "mean_us": float(arr.mean())}


def _case_cuts(case: np.ndarray, n_batches: int) -> list[int]:
    bounds = np.flatnonzero(case[1:] != case[:-1]) + 1
    per = max(1, len(bounds) // n_batches)
    cuts = [0] + [int(bounds[i]) for i in range(per - 1, len(bounds), per)]
    if cuts[-1] != case.size:
        cuts.append(case.size)
    return cuts


def run(num_cases: int = 50_000, num_activities: int = 8, seed: int = 11,
        num_batches: int = 8, requests_per_client: int = 6,
        out_json: str | None = "BENCH_serving.json", smoke: bool = False):
    import jax

    import repro
    from repro.core.eventframe import CASE, EventFrame
    from repro.data import synthetic
    from repro.dataset import engines as ds_engines
    from repro.query.statecache import state_cache
    from repro.service import Ingestor, MiningService, ServiceError, serve, \
        to_jsonable
    from repro.storage import edf

    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=num_activities,
                                       seed=seed)
    n = frame.nrows
    emit("serving/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")

    def _slice(a, b):
        return EventFrame({k: v[a:b] for k, v in frame.columns.items()},
                          {k: v[a:b] for k, v in frame.valid.items()},
                          frame.rows_valid()[a:b])

    cuts = _case_cuts(np.asarray(frame.columns[CASE]), num_batches)
    batches = [(cuts[i], cuts[i + 1]) for i in range(len(cuts) - 1)]
    half = max(1, len(batches) // 2)

    root = tempfile.mkdtemp()
    bdir, pdir = os.path.join(root, "batches"), os.path.join(root, "parts")
    os.makedirs(bdir)
    for i, (a, b) in enumerate(batches[:half]):
        edf.write(os.path.join(bdir, f"batch_{i:04d}.edf"), _slice(a, b),
                  tables, version=3)
    ing = Ingestor(pdir, bdir, partition_rows=max(n // 3, 1),
                   row_group_rows=max(n // 40, 1), poll_interval=0.01)
    ing.run_once()
    # capacity pinned to the log's final case count: the spec fingerprint
    # never moves, so per-group states cached now stay valid to the end
    svc = MiningService(ing, case_capacity=num_cases)
    state_cache().clear()
    ds_engines.clear_result_cache()
    for verb in VERBS:                          # compile + warm the cache
        svc.collect(verb, engine="streaming")

    def client(times: list, stop_at: float, results: list | None = None):
        done = 0
        while done < requests_per_client and time.monotonic() < stop_at:
            verb = VERBS[done % len(VERBS)]
            try:
                t0 = time.perf_counter()
                out = svc.collect(verb, engine="streaming")
                times.append(time.perf_counter() - t0)
                if results is not None:
                    results.append((verb, out["snapshot"],
                                    json.dumps(out["result"])))
                done += 1
            except ServiceError:
                time.sleep(0.02)

    # ---- static sweep: frozen partitions, growing client counts
    static = []
    for nthreads in THREAD_SWEEP:
        times: list[float] = []
        stop_at = time.monotonic() + 60
        threads = [threading.Thread(target=client, args=(times, stop_at))
                   for _ in range(nthreads)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        point = {"threads": nthreads, **_percentiles(times),
                 "qps": len(times) / max(wall, 1e-9)}
        static.append(point)
        emit(f"serving/static_t{nthreads}", point["p50_us"] / 1e6,
             f"p99_us={point['p99_us']:.0f};qps={point['qps']:.0f}")

    # ---- live phase: ingest thread appends while clients query
    def produce():
        for i, (a, b) in enumerate(batches[half:-1], start=half):
            edf.write(os.path.join(bdir, f"batch_{i:04d}.edf"),
                      _slice(a, b), tables, version=3)
            time.sleep(0.01)

    live_times: list[float] = []
    live_results: list = []
    retries0 = svc.retries
    producer = threading.Thread(target=produce)
    stop_at = time.monotonic() + 120
    clients = [threading.Thread(target=client,
                                args=(live_times, stop_at, live_results))
               for _ in range(max(THREAD_SWEEP))]
    t0 = time.perf_counter()
    producer.start()
    ing.start()
    for t in clients:
        t.start()
    for t in clients:
        t.join()
    producer.join()
    while ing.run_once():                       # drain the tail
        pass
    ing.stop()
    live_wall = time.perf_counter() - t0
    live = {**_percentiles(live_times),
            "qps": len(live_times) / max(live_wall, 1e-9),
            "retries": svc.retries - retries0,
            "batches_ingested": len(batches) - 1 - half}
    emit("serving/live", live["p50_us"] / 1e6,
         f"p99_us={live['p99_us']:.0f};qps={live['qps']:.0f};"
         f"retries={live['retries']}")

    # every live result must re-mine bitwise-equal from its claimed rows
    checked = 0
    for verb, claim, result_json in live_results[:8]:
        ref = repro.open(_slice(0, claim["rows"]), tables=tables,
                         num_cases=claim["num_cases"]).collect(
                             verb, engine="eager")
        assert result_json == json.dumps(to_jsonable(ref.result)), \
            f"{verb} diverged at a {claim['rows']}-row snapshot"
        checked += 1
    emit("serving/live_parity", 0.0,
         f"checked={checked}/{len(live_results)}")

    # ---- append delta: one more batch, then a warm re-collect
    sc = state_cache()
    svc.collect("dfg", engine="streaming")      # states for current groups
    hits0, a = sc.hits, batches[-1]
    edf.write(os.path.join(bdir, f"batch_{len(batches) - 1:04d}.edf"),
              _slice(a[0], a[1]), tables, version=3)
    ing.run_once()
    ds_engines.clear_result_cache()             # isolate the state cache
    t0 = time.perf_counter()
    out = svc.collect("dfg", engine="streaming")
    us_delta = (time.perf_counter() - t0) * 1e6
    rep = out["report"]
    append_delta = {
        "groups_cached": rep["groups_cached"],
        "groups_folded": rep["groups_folded"],
        "groups_read": rep["groups_read"],
        "state_cache_hit_delta": sc.hits - hits0,
        "us_recollect": us_delta,
    }
    emit("serving/append_delta", us_delta / 1e6,
         f"cached={rep['groups_cached']};folded={rep['groups_folded']};"
         f"hit_delta={append_delta['state_cache_hit_delta']}")
    ref = repro.open(frame, tables=tables,
                     num_cases=out["snapshot"]["num_cases"]).collect(
                         "dfg", engine="eager")
    assert json.dumps(out["result"]) == json.dumps(to_jsonable(ref.result)), \
        "post-ingest service result diverged from scratch re-mine"

    # ---- HTTP round: the same query through the JSON API
    httpd = serve(svc, port=0)
    port = httpd.server_address[1]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    http_times = []
    try:
        url = f"http://127.0.0.1:{port}/collect?verb=dfg&engine=streaming"
        for _ in range(8):
            t0 = time.perf_counter()
            with urllib.request.urlopen(url, timeout=60) as r:
                assert json.loads(r.read())["ok"]
            http_times.append(time.perf_counter() - t0)
    finally:
        httpd.shutdown()
    http = _percentiles(http_times)
    emit("serving/http", http["p50_us"] / 1e6, f"p99_us={http['p99_us']:.0f}")

    if smoke:
        assert live["requests"] > 0, "no queries completed during live ingest"
        assert checked > 0, "no live result was parity-checked"
        assert append_delta["groups_cached"] > 0, \
            "post-append re-collect found no cached group states"
        assert append_delta["state_cache_hit_delta"] > 0, \
            "post-append re-collect never hit the warm state cache"
        assert append_delta["groups_read"] <= rep["groups_folded"], \
            "re-collect decoded more than the appended delta"

    if out_json:
        artifact = {
            "bench": "serving",
            "jax": jax.__version__,
            "python": platform.python_version(),
            "backend": jax.default_backend(),
            "config": {"num_cases": num_cases,
                       "num_activities": num_activities, "events": n,
                       "batches": len(batches),
                       "requests_per_client": requests_per_client},
            "static_sweep": static,
            "live": live,
            "append_delta": append_delta,
            "http": http,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"serving/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return {"static": static, "live": live, "append_delta": append_delta}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small log; asserts live parity + warm cache hits")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    header()
    if args.smoke:
        run(num_cases=8_000, requests_per_client=4, out_json=args.out,
            smoke=True)
    else:
        run(num_cases=200_000 if args.full else 50_000, out_json=args.out)


if __name__ == "__main__":
    main()
