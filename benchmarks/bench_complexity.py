"""Paper Tables 3/4: empirical complexity of filtering and DFG.

Times each implementation across a geometric ladder of N and fits the
log-log slope — the measured complexity exponent. Expected: ~1.0 for all
columnar paths (Table 3/4 'dataframe' rows) and ~1.0 avg for the classic
log (its worst cases are map-collision pathologies CPython hides)."""
from __future__ import annotations

import numpy as np
import jax

from repro.core import ClassicEventLog, dfg
from repro.core.eventframe import ACTIVITY, CASE
from repro.core import filtering, ops
from repro.data import synthetic

from .common import emit, timeit


def _slope(ns, ts):
    return float(np.polyfit(np.log(ns), np.log(ts), 1)[0])


def run(sizes=(2_000, 8_000, 32_000, 128_000)):
    t_filter_classic, t_filter_frame = [], []
    t_dfg_classic, t_dfg_frame, t_dfg_matmul = [], [], []
    ns = []
    for n_cases in sizes:
        frame, tables = synthetic.generate(num_cases=n_cases, num_activities=26,
                                           seed=7)
        log = ClassicEventLog.from_eventframe(frame, tables)
        n = frame.nrows
        ns.append(n)
        acts = set(tables[ACTIVITY][:5])

        t_filter_classic.append(timeit(
            lambda: log.filter_events(ACTIVITY, acts), repeat=1))
        ids = np.asarray([tables[ACTIVITY].index(a) for a in acts])
        t_filter_frame.append(timeit(lambda: jax.block_until_ready(
            ops.proj(frame, filtering.isin_mask(
                frame[ACTIVITY], ids)).rows_valid())))
        t_dfg_classic.append(timeit(lambda: log.dfg_iterative(), repeat=1))
        t_dfg_frame.append(timeit(lambda: jax.block_until_ready(
            dfg(frame, 26, method="shift").counts)))
        t_dfg_matmul.append(timeit(lambda: jax.block_until_ready(
            dfg(frame, 26, method="matmul").counts)))

    for name, ts in [("filter_classic_log", t_filter_classic),
                     ("filter_dataframe", t_filter_frame),
                     ("dfg_classic_iteration", t_dfg_classic),
                     ("dfg_dataframe_shift", t_dfg_frame),
                     ("dfg_dataframe_matmul", t_dfg_matmul)]:
        emit(f"complexity/{name}", ts[-1],
             f"exponent={_slope(ns, ts):.2f};N_max={ns[-1]}")
