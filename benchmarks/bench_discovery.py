"""Discovery-workload benchmark: alpha + heuristics on the columnar state.

Times the full discovery pipeline — accumulate DFG + L2-loop counts
(whole-log jitted AND streamed over EDF row groups), finalize the alpha and
heuristics models, replay conformance — and asserts the streamed state is
bitwise-identical to the whole-log pass.  Writes the ``BENCH_discovery.json``
trajectory artifact so future PRs diff against a stable baseline.

Standalone:  python benchmarks/bench_discovery.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only discovery
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode: python benchmarks/bench_discovery.py
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np


def run(num_cases: int = 100_000, num_activities: int = 12, seed: int = 7,
        out_json: str | None = "BENCH_discovery.json"):
    import jax

    from repro.core import ChunkedEventFrame, conformance, discovery
    from repro.core.eventframe import ACTIVITY, CASE
    from repro.data import synthetic
    from repro.storage import edf

    a = num_activities
    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=a, seed=seed)
    n = frame.nrows
    emit("discovery/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")
    results: dict = {}

    # ---- whole-log accumulation (single-chunk special case)
    t_state = timeit(lambda: jax.block_until_ready(
        discovery.discovery_state(frame, a).dfg.counts))
    emit("discovery/state_whole_log", t_state, f"events_per_s={n/t_state:.0f}")
    results["state_whole_log"] = {"us_per_call": t_state * 1e6,
                                  "events_per_s": n / t_state}
    state = discovery.discovery_state(frame, a)

    # ---- streamed accumulation over EDF row groups (out-of-core path)
    d = tempfile.mkdtemp()
    path = os.path.join(d, "disc.edf")
    edf.write(path, frame, tables, codec="zlib1",
              row_group_rows=max(1, n // 12))
    src = ChunkedEventFrame.from_edf(path, columns=[CASE, ACTIVITY])
    t0 = time.perf_counter()
    streamed = discovery.streaming_discovery_state(src, a)
    jax.block_until_ready(streamed.dfg.counts)
    t_stream = time.perf_counter() - t0
    emit("discovery/state_streamed", t_stream,
         f"events_per_s={n/t_stream:.0f};groups={edf.num_row_groups(path)}")
    results["state_streamed"] = {"us_per_call": t_stream * 1e6,
                                 "events_per_s": n / t_stream}
    for name, ref, got in (("counts", state.dfg.counts, streamed.dfg.counts),
                           ("l2", state.l2_counts, streamed.l2_counts)):
        assert (np.asarray(ref) == np.asarray(got)).all(), name
    emit("discovery/bitwise_equal", 0.0, "streamed==whole_log")
    os.unlink(path)

    # ---- finalize: the miners themselves (model construction)
    t_alpha = timeit(lambda: discovery.discover_alpha(state.dfg), repeat=3)
    model = discovery.discover_alpha(state.dfg)
    emit("discovery/alpha_finalize", t_alpha, f"places={model.num_places}")
    results["alpha_finalize"] = {"us_per_call": t_alpha * 1e6,
                                 "num_places": model.num_places}
    t_heur = timeit(lambda: jax.block_until_ready(
        discovery.discover_heuristics(state).dependency), repeat=3)
    net = discovery.discover_heuristics(state)
    n_edges = int(np.asarray(net.graph).sum())
    emit("discovery/heuristics_finalize", t_heur, f"edges={n_edges}")
    results["heuristics_finalize"] = {"us_per_call": t_heur * 1e6,
                                      "num_edges": n_edges}

    # ---- conformance replay against the discovered models
    t_conf = timeit(lambda: jax.block_until_ready(
        conformance.alpha_fitness(state.dfg, model)), repeat=3)
    fit_a = float(conformance.alpha_fitness(state.dfg, model))
    fit_h = float(conformance.heuristics_fitness(state.dfg, net))
    conf_fp = float(conformance.footprint_conformance(state.dfg, model))
    emit("discovery/replay", t_conf,
         f"alpha_fitness={fit_a:.3f};heuristics_fitness={fit_h:.3f}"
         f";footprint_conformance={conf_fp:.3f}")
    results["replay"] = {"us_per_call": t_conf * 1e6,
                         "alpha_fitness": fit_a, "heuristics_fitness": fit_h,
                         "footprint_conformance": conf_fp}
    assert fit_a == 1.0 and conf_fp == 1.0  # self-replay is exact

    if out_json:
        artifact = {
            "bench": "discovery",
            "num_cases": num_cases,
            "n_events": n,
            "num_activities": a,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "results": results,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"discovery/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (~10^5 events)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale run (10^6+ events)")
    ap.add_argument("--cases", type=int, default=None)
    ap.add_argument("--out", default="BENCH_discovery.json")
    args = ap.parse_args(argv)
    if args.cases:
        cases = args.cases
    elif args.full:
        cases = 1_000_000
    elif args.smoke:
        cases = 20_000
    else:
        cases = 100_000
    header()
    run(num_cases=cases, out_json=args.out)


if __name__ == "__main__":
    main()
