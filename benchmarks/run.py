"""Benchmark harness: one module per paper table. CSV: name,us_per_call,derived.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table5,table6]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_complexity, bench_dataset, bench_discovery,
               bench_distributed_dfg, bench_fusion, bench_graph,
               bench_kernels, bench_query, bench_segment_ops, bench_serving,
               bench_streaming, bench_table1_loading, bench_table2_sizes,
               bench_table5_ops, bench_table6_biglogs, bench_variants_prune,
               bench_window)
from .common import header

SUITES = {
    "table1": lambda full: bench_table1_loading.run(
        num_cases=200_000 if full else 50_000),
    "table2": lambda full: bench_table2_sizes.run(
        num_cases=100_000 if full else 20_000),
    "table5": lambda full: bench_table5_ops.run(scale=1.0 if full else 0.3),
    "table6": lambda full: bench_table6_biglogs.run(
        scale=1.0 if full else 0.05, levels=(1, 2, 3, 4, 5)),
    "complexity": lambda full: bench_complexity.run(
        sizes=(2_000, 8_000, 32_000, 128_000, 512_000) if full
        else (2_000, 8_000, 32_000)),
    "kernels": lambda full: bench_kernels.run(smoke=not full),
    # primitive-level Pallas-interpret vs XLA timings; always writes the
    # BENCH_segment_ops.json trajectory artifact (perf baseline for PRs)
    "segment_ops": lambda full: bench_segment_ops.run(
        full=full, out_json="BENCH_segment_ops.json"),
    # alpha + heuristics miners on the columnar state; always writes the
    # BENCH_discovery.json trajectory artifact (smoke-sized unless --full)
    "discovery": lambda full: bench_discovery.run(
        num_cases=200_000 if full else 20_000,
        out_json="BENCH_discovery.json"),
    # zone-map pushdown selectivity sweep; always writes the
    # BENCH_query.json trajectory artifact (skip-ratio baseline for PRs)
    "query": lambda full: bench_query.run(
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_query.json"),
    # Dataset facade: multi-log pruning, 1-vs-N union overhead, and the
    # engine-dispatch crossover; writes BENCH_dataset.json
    "dataset": lambda full: bench_dataset.run(
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_dataset.json"),
    # fused multi-verb collection vs separate scans + prefetch sweep;
    # writes BENCH_fusion.json
    "fusion": lambda full: bench_fusion.run(
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_fusion.json"),
    # variant-band sketch pruning selectivity sweep (incl. fused 4-verb
    # collection); writes BENCH_variants.json
    "variants_prune": lambda full: bench_variants_prune.run(
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_variants.json"),
    # sliding windows as merge-trees over cached group states + the
    # incremental append scenario; writes BENCH_window.json
    "window": lambda full: bench_window.run(
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_window.json"),
    # the live mining service: concurrent query latency with and without
    # live ingest + the post-append warm-cache delta; writes
    # BENCH_serving.json
    "serving": lambda full: bench_serving.run(
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_serving.json"),
    # semiring closures vs host NumPy Floyd–Warshall + the mined graph
    # verbs; writes BENCH_graph.json
    "graph": lambda full: bench_graph.run(
        dense=(512, 0.5) if full else (384, 0.5),
        num_cases=200_000 if full else 50_000,
        out_json="BENCH_graph.json"),
    "distributed": lambda full: bench_distributed_dfg.run(),
    "streaming": lambda full: bench_streaming.run(
        num_cases=2_000_000 if full else 100_000),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (Table 6 at 10^6..5x10^6 cases)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    header()
    failed = []
    for name, fn in SUITES.items():
        if only and name not in only:
            continue
        try:
            fn(args.full)
        except Exception as e:
            failed.append(name)
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"failed suites: {failed}")


if __name__ == "__main__":
    main()
