"""Graph-query benchmark: semiring closures vs host NumPy Floyd–Warshall.

Sweeps dense random process graphs over (size, density) and times the
repeated-squaring device closures (``ceil(log2 n)`` jitted semiring
matmuls) against the n-sweep NumPy Floyd–Warshall on the host:

* ``reach``    — boolean transitive closure (thresholded MXU matmuls)
* ``widest``   — max-min bottleneck capacities
* ``shortest`` — min-plus distances (integer edge weights: exact)

Every configuration asserts the device result is *exactly* the host FW
result (boolean/tropical candidates are computed identically op for op;
integer-valued sums stay exact below 2^24).

The dense case times reachability against the standard float32 FW
relaxation (same operand layout as the kernels) and — for honesty on
CPU — against the bitset-optimized boolean FW, which trades the float
matrix for byte-wide AND/OR and is bandwidth-bound rather than
FLOP-bound.  ``--smoke`` asserts speedup >= 1 on the float32 baseline;
the matmul closure does ``log2(n)`` products of n^3 MACs, so it only
wins where the matmul unit (BLAS on CPU, the MXU on TPU) buys more than
the log-factor — which is exactly what the sweep shows.  A mined
end-to-end section times the ``graph`` / ``bottleneck_paths`` verbs on
a synthetic log.  Writes the ``BENCH_graph.json`` trajectory artifact.

Standalone:  python benchmarks/bench_graph.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only graph
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys

if __package__ in (None, ""):  # script mode: python benchmarks/bench_graph.py
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np


# ------------------------------------------------- host FW oracles
def _fw_bool(adj: np.ndarray) -> np.ndarray:
    """Bitset-optimized boolean FW (byte-wide AND/OR; the host's best)."""
    r = adj | np.eye(adj.shape[0], dtype=bool)
    for k in range(adj.shape[0]):
        r |= r[:, k, None] & r[None, k, :]
    return r


def _fw_bool_f32(adj: np.ndarray) -> np.ndarray:
    """Standard float32 FW transitive closure (max-min over {0, 1})."""
    d = adj.astype(np.float32)
    np.fill_diagonal(d, 1.0)
    for k in range(adj.shape[0]):
        d = np.maximum(d, np.minimum(d[:, k, None], d[None, k, :]))
    return d > 0


def _fw_widest(cap: np.ndarray) -> np.ndarray:
    d = np.where(np.eye(cap.shape[0], dtype=bool), np.inf, cap)
    d = d.astype(np.float32)
    for k in range(cap.shape[0]):
        d = np.maximum(d, np.minimum(d[:, k, None], d[None, k, :]))
    return d


def _fw_shortest(w: np.ndarray) -> np.ndarray:
    d = np.where(np.eye(w.shape[0], dtype=bool), 0.0, w).astype(np.float32)
    for k in range(w.shape[0]):
        d = np.minimum(d, d[:, k, None] + d[None, k, :])
    return d


def _random_graph(n: int, density: float, seed: int):
    rng = np.random.default_rng(seed)
    adj = rng.random((n, n)) < density
    np.fill_diagonal(adj, False)
    freq = np.where(adj, rng.integers(1, 1000, (n, n)), 0).astype(np.float32)
    cap = np.where(adj, freq, -np.inf).astype(np.float32)
    cost = np.where(adj, freq, np.inf).astype(np.float32)
    return adj, cap, cost


def run(sizes=((48, 0.25), (128, 0.25), (256, 0.5)),
        dense=(384, 0.5), num_cases: int = 50_000,
        assert_speedup: bool = False,
        out_json: str | None = "BENCH_graph.json"):
    import jax

    from repro.kernels.graph_ops import (bool_closure, maxmin_closure,
                                         minplus_closure)

    jit_reach = jax.jit(lambda a: bool_closure(a))
    jit_widest = jax.jit(lambda c: maxmin_closure(c))
    jit_shortest = jax.jit(lambda c: minplus_closure(c))

    results: dict = {"sweep": []}

    for n, density in sizes:
        adj, cap, cost = _random_graph(n, density, seed=n)
        tag = f"n{n}_d{density:g}"

        t_reach = timeit(lambda: jax.block_until_ready(jit_reach(adj)))
        t_fw_reach = timeit(lambda: _fw_bool(adj))
        assert np.array_equal(np.asarray(jit_reach(adj)), _fw_bool(adj)), \
            f"reach parity {tag}"

        t_wide = timeit(lambda: jax.block_until_ready(jit_widest(cap)))
        t_fw_wide = timeit(lambda: _fw_widest(cap))
        assert np.array_equal(np.asarray(jit_widest(cap)),
                              _fw_widest(cap)), f"widest parity {tag}"

        t_short = timeit(lambda: jax.block_until_ready(jit_shortest(cost)))
        t_fw_short = timeit(lambda: _fw_shortest(cost))
        assert np.array_equal(np.asarray(jit_shortest(cost)),
                              _fw_shortest(cost)), f"shortest parity {tag}"

        speedups = {"reach": t_fw_reach / t_reach,
                    "widest": t_fw_wide / t_wide,
                    "shortest": t_fw_short / t_short}
        emit(f"graph/reach_{tag}", t_reach,
             f"fw={t_fw_reach*1e6:.1f}us;speedup={speedups['reach']:.2f}x")
        emit(f"graph/widest_{tag}", t_wide,
             f"fw={t_fw_wide*1e6:.1f}us;speedup={speedups['widest']:.2f}x")
        emit(f"graph/shortest_{tag}", t_short,
             f"fw={t_fw_short*1e6:.1f}us;speedup={speedups['shortest']:.2f}x")
        results["sweep"].append({
            "n": n, "density": density,
            "device_us": {"reach": t_reach * 1e6, "widest": t_wide * 1e6,
                          "shortest": t_short * 1e6},
            "host_fw_us": {"reach": t_fw_reach * 1e6,
                           "widest": t_fw_wide * 1e6,
                           "shortest": t_fw_short * 1e6},
            "speedup": speedups, "parity": "bitwise"})

    # ---- dense case: reachability closure vs standard f32 FW
    n, density = dense
    adj, _, _ = _random_graph(n, density, seed=n)
    t_reach = timeit(lambda: jax.block_until_ready(jit_reach(adj)))
    t_fw_f32 = timeit(lambda: _fw_bool_f32(adj))
    t_fw_bits = timeit(lambda: _fw_bool(adj))
    assert np.array_equal(np.asarray(jit_reach(adj)), _fw_bool_f32(adj)), \
        "dense reach parity"
    speedup = t_fw_f32 / t_reach
    emit(f"graph/dense_reach_n{n}", t_reach,
         f"fw_f32={t_fw_f32*1e6:.1f}us;fw_bitset={t_fw_bits*1e6:.1f}us"
         f";speedup={speedup:.2f}x")
    results["dense_case"] = {
        "n": n, "density": density, "device_us": t_reach * 1e6,
        "host_fw_f32_us": t_fw_f32 * 1e6,
        "host_fw_bitset_us": t_fw_bits * 1e6,
        "speedup_vs_f32_fw": speedup, "parity": "bitwise"}
    if assert_speedup:
        assert speedup >= 1.0, \
            f"dense case: closure slower than host f32 FW ({speedup:.2f}x)"

    # ---- mined end-to-end: log -> one DFG fold -> graph verbs
    import repro
    from repro.core import ops
    from repro.core.eventframe import CASE, TIMESTAMP
    from repro.data import synthetic

    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=24, seed=11)
    frame = ops.sort(frame, (TIMESTAMP, CASE))
    ds = repro.open(frame, tables=tables)
    nev = frame.nrows

    t_graph = timeit(lambda: jax.block_until_ready(ds.graph().freq))
    emit("graph/verb_compile", t_graph,
         f"events={nev};events_per_s={nev/t_graph:.0f}")
    results["verb_compile"] = {"us_per_call": t_graph * 1e6,
                               "events_per_s": nev / t_graph}

    t_bott = timeit(lambda: jax.block_until_ready(ds.bottlenecks().widest))
    bp = ds.bottlenecks()
    emit("graph/verb_bottlenecks", t_bott,
         f"bottleneck={bp.bottleneck:g};hops={len(bp.path)}")
    results["verb_bottlenecks"] = {"us_per_call": t_bott * 1e6,
                                   "bottleneck": bp.bottleneck,
                                   "path_len": len(bp.path)}
    assert bp.bottleneck > 0 and bp.path, "mined log has an end-to-end path"

    if out_json:
        artifact = {
            "bench": "graph",
            "num_cases": num_cases,
            "n_events": nev,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "platform": platform.platform(),
            "results": results,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"graph/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep; asserts dense-case speedup >= 1")
    ap.add_argument("--full", action="store_true",
                    help="larger sweep (512-node dense case)")
    ap.add_argument("--out", default="BENCH_graph.json")
    args = ap.parse_args(argv)
    if args.full:
        sizes = ((48, 0.25), (128, 0.25), (256, 0.5))
        dense, cases = (512, 0.5), 200_000
    else:
        sizes = ((48, 0.25), (128, 0.25), (256, 0.5))
        dense, cases = (384, 0.5), 20_000 if args.smoke else 50_000
    header()
    run(sizes=sizes, dense=dense, num_cases=cases,
        assert_speedup=args.smoke, out_json=args.out)


if __name__ == "__main__":
    main()
