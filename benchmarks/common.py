"""Benchmark helpers: timing + CSV emission (name,us_per_call,derived)."""
from __future__ import annotations

import time


def timeit(fn, repeat: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


ROWS: list[tuple[str, float, str]] = []


def emit(name: str, seconds: float, derived: str = ""):
    ROWS.append((name, seconds * 1e6, derived))
    print(f"{name},{seconds*1e6:.1f},{derived}", flush=True)


def header():
    print("name,us_per_call,derived", flush=True)
