"""Query-pushdown benchmark: selectivity sweep of zone-map pruned scans.

Writes a synthetic log as an EDFV0003 file, then mines the DFG through
``repro.query.execute`` under case-band predicates of decreasing
selectivity, comparing the pruned scan against the identical plan with
pruning disabled (the full-scan baseline).  Reports row-groups skipped
and on-disk bytes read for each point, asserts the two results are
bitwise identical, and writes the ``BENCH_query.json`` trajectory
artifact (the smoke run additionally asserts a positive skip ratio — the
zone maps must actually refuse I/O).

Standalone:  python benchmarks/bench_query.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only query
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode: python benchmarks/bench_query.py
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np

SELECTIVITIES = (0.01, 0.05, 0.25, 1.0)


def run(num_cases: int = 50_000, num_activities: int = 16, seed: int = 11,
        num_groups: int = 32, out_json: str | None = "BENCH_query.json"):
    import jax

    from repro.core import CASE, engine, ops
    from repro.core.dfg import dfg_kernel
    from repro.data import synthetic
    from repro.query import Plan, col, execute
    from repro.storage import edf

    a = num_activities
    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases, num_activities=a,
                                       seed=seed, extra_numeric_attrs=1)
    n = frame.nrows
    emit("query/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")

    d = tempfile.mkdtemp()
    path = os.path.join(d, "query.edf")
    t0 = time.perf_counter()
    edf.write(path, frame, tables, codec="zlib1",
              row_group_rows=max(1, n // num_groups))
    emit("query/write_v3", time.perf_counter() - t0,
         f"groups={edf.num_row_groups(path)};"
         f"bytes={os.path.getsize(path)}")

    kernel = dfg_kernel(a)
    sweep = []
    for sel in SELECTIVITIES:
        hi = max(0, int(num_cases * sel) - 1)
        plan = Plan(path).filter(col(CASE).between(0, hi))

        pruned, rep = execute(plan, mine=kernel)
        us_pruned = timeit(lambda: execute(plan, mine=kernel))
        full, rep_full = execute(plan, mine=kernel, prune=False)
        us_full = timeit(lambda: execute(plan, mine=kernel, prune=False))

        for nm in ("counts", "starts", "ends"):
            got = np.asarray(getattr(pruned, nm))
            ref = np.asarray(getattr(full, nm))
            assert (got == ref).all(), f"pruned != full scan at sel={sel}:{nm}"
        point = {
            "selectivity": sel,
            "groups_total": rep.groups_total,
            "groups_skipped": rep.groups_skipped,
            "skip_ratio": rep.skip_ratio,
            "bytes_read": rep.bytes_read,
            "bytes_full": rep_full.bytes_read,
            "bytes_saved_ratio": rep.bytes_saved_ratio,
            "us_pruned": us_pruned * 1e6,
            "us_full_scan": us_full * 1e6,
            "df_pairs": int(np.asarray(pruned.counts).sum()),
        }
        sweep.append(point)
        emit(f"query/pruned_scan_sel={sel}", us_pruned,
             f"skipped={rep.groups_skipped}/{rep.groups_total};"
             f"bytes={rep.bytes_read}/{rep_full.bytes_read}")
        emit(f"query/full_scan_sel={sel}", us_full, f"bytes={rep_full.bytes_read}")

    # eager baseline: load everything, filter in memory, mine
    whole, _ = edf.read(path)

    def eager():
        c = whole[CASE]
        hi = int(num_cases * SELECTIVITIES[0]) - 1
        f = ops.proj(whole, (c >= 0) & (c <= hi))
        return engine.run_single(kernel, f)

    us_eager = timeit(eager)
    emit("query/eager_filter_then_mine", us_eager,
         f"sel={SELECTIVITIES[0]}")

    best_skip = max(p["skip_ratio"] for p in sweep)
    assert best_skip > 0.0, "zone maps skipped nothing on a selective scan"
    assert min(p["bytes_read"] for p in sweep) < sweep[-1]["bytes_full"], \
        "pruned scan never read fewer bytes than the full scan"

    if out_json:
        artifact = {
            "bench": "query",
            "jax": jax.__version__,
            "python": platform.python_version(),
            "backend": jax.default_backend(),
            "config": {"num_cases": num_cases, "num_activities": a,
                       "events": n, "row_groups": edf.num_row_groups(path)},
            "sweep": sweep,
            "eager_us": us_eager * 1e6,
            "max_skip_ratio": best_skip,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"query/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; asserts skip ratio > 0 and parity")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_query.json")
    args = ap.parse_args()
    header()
    cases = 200_000 if args.full else (20_000 if args.smoke else 50_000)
    sweep = run(num_cases=cases, out_json=args.out)
    if args.smoke:
        print(f"query/SMOKE_OK,0.0,max_skip_ratio="
              f"{max(p['skip_ratio'] for p in sweep):.3f}", flush=True)


if __name__ == "__main__":
    main()
