"""Paper Table 5: real-life-scale operations on dataframes.

Per log: size on disk, load (all attrs vs 2 cols), filter on most common
activity, DFG via shifting-and-counting. Log profiles mirror the paper's
five real-life logs (events/cases/classes)."""
from __future__ import annotations

import os
import tempfile

import jax

from repro.core import dfg
from repro.core.eventframe import ACTIVITY, CASE
from repro.core import filtering, ops
from repro.data import synthetic
from repro.storage import edf

from .common import emit, timeit

# (name, events~, cases, classes) scaled ~1/10 of the paper's logs by default
PROFILES = [
    ("roadtraffic", 15_370, 11),
    ("bpic2017_o", 42_995, 8),
    ("bpic2017_a", 31_509, 26),
    ("bpic2018", 43_809, 41),
    ("bpic2019", 50_000, 42),
]


def run(scale=1.0):
    for name, cases, classes in PROFILES:
        n_cases = max(100, int(cases * scale))
        frame, tables = synthetic.generate(num_cases=n_cases,
                                           num_activities=classes, seed=42)
        a = classes
        d = tempfile.mkdtemp()
        p = os.path.join(d, f"{name}.edf")
        edf.write(p, frame, tables, codec="zlib1")
        emit(f"table5/{name}/size", 0.0,
             f"events={frame.nrows};bytes={os.path.getsize(p)}")
        t = timeit(lambda: edf.read(p), repeat=2)
        emit(f"table5/{name}/load_all", t, f"events_per_s={frame.nrows/t:.0f}")
        t = timeit(lambda: edf.read(p, columns=[CASE, ACTIVITY]), repeat=2)
        emit(f"table5/{name}/load_2col", t, f"events_per_s={frame.nrows/t:.0f}")

        top = filtering.most_common_activity(frame, a)
        f = jax.jit(lambda fr: ops.proj(
            fr, filtering.isin_mask(fr[ACTIVITY], top[None])).rows_valid().sum())
        t = timeit(lambda: f(frame).block_until_ready())
        emit(f"table5/{name}/filter_top_activity", t,
             f"events_per_s={frame.nrows/t:.0f}")
        t = timeit(lambda: jax.block_until_ready(dfg(frame, a, method='shift').counts))
        emit(f"table5/{name}/dfg_shift_count", t,
             f"events_per_s={frame.nrows/t:.0f}")
