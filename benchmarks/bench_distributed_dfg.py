"""Distributed DFG scaling: shard_map map-reduce over 1..8 host devices.

Runs in a subprocess so the 8-device XLA flag never leaks into the parent
(tests/benches must see 1 device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import jax
import numpy as np
from repro.data import synthetic
from repro.core import dfg
from repro.distributed.dfg import dfg_sharded_host

frame, tables = synthetic.generate(num_cases=200_000, num_activities=26, seed=5)
n = frame.nrows
# pad to multiple of 8 for even sharding
pad = (-n) % 8
if pad:
    import jax.numpy as jnp
    from repro.core.eventframe import EventFrame
    cols = {k: jnp.pad(v, (0, pad), constant_values=-1) for k, v in frame.columns.items()}
    rv = jnp.pad(frame.rows_valid(), (0, pad))
    frame = EventFrame(cols, {}, rv)

ref = np.asarray(dfg(frame, 26, method="segment").counts)
out = {}
for shards in (1, 2, 4, 8):
    f = lambda: jax.block_until_ready(dfg_sharded_host(frame, 26, shards))
    f()
    t0 = time.perf_counter(); f(); dt = time.perf_counter() - t0
    got = np.asarray(dfg_sharded_host(frame, 26, shards).counts)
    out[f"shards_{shards}"] = {"seconds": dt, "events_per_s": n / dt,
                               "correct": bool((got == ref).all())}
print(json.dumps(out))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                         text=True, env=env, timeout=600)
    if res.returncode != 0:
        emit("distributed_dfg/error", 0.0, res.stderr.strip()[-200:])
        return
    data = json.loads(res.stdout.strip().splitlines()[-1])
    base = data["shards_1"]["seconds"]
    for k, v in data.items():
        emit(f"distributed_dfg/{k}", v["seconds"],
             f"events_per_s={v['events_per_s']:.0f};correct={v['correct']};"
             f"speedup={base/v['seconds']:.2f}x")
