"""Paper Table 2: size on disk across formats/codecs.

XES (row XML) vs CSV vs JSONL (Avro stand-in) vs EDF raw / zlib1 (Snappy
role) / zlib9 (Gzip role)."""
from __future__ import annotations

import csv
import gzip
import os
import tempfile

import numpy as np

from repro.core import ClassicEventLog
from repro.data import synthetic
from repro.storage import edf, rowlog, xes

from .common import emit


def run(num_cases=20_000):
    frame, tables = synthetic.generate(num_cases=num_cases, num_activities=26,
                                       seed=1, extra_numeric_attrs=2)
    d = tempfile.mkdtemp()
    log = ClassicEventLog.from_eventframe(frame, tables)

    paths = {}
    paths["xes"] = os.path.join(d, "log.xes")
    xes.write(paths["xes"], log)
    paths["csv"] = os.path.join(d, "log.csv")
    data = frame.to_numpy()
    cols = sorted(data)
    with open(paths["csv"], "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(cols)
        for i in range(frame.nrows):
            w.writerow([data[c][i] for c in cols])
    paths["jsonl(avro-role)"] = os.path.join(d, "log.jsonl")
    rowlog.write(paths["jsonl(avro-role)"], log)
    for codec, label in [("raw", "edf-raw"), ("zlib1", "edf-zlib1(snappy-role)"),
                         ("zlib9", "edf-zlib9(gzip-role)")]:
        p = os.path.join(d, f"log_{codec}.edf")
        edf.write(p, frame, tables, codec=codec)
        paths[label] = p
    paths["xes.gz"] = os.path.join(d, "log.xes.gz")
    with open(paths["xes"], "rb") as fi, gzip.open(paths["xes.gz"], "wb") as fo:
        fo.write(fi.read())

    base = os.path.getsize(paths["xes"])
    for label, p in paths.items():
        sz = os.path.getsize(p)
        emit(f"table2/size_{label}", 0.0,
             f"bytes={sz};ratio_vs_xes={sz/base:.3f}")
