"""Variant-band pruning benchmark: header sketches vs full decode.

Sweeps ``variant_in`` selectivity over a partitioned synthetic log and
measures what resolving the per-case keep mask from the header sketch
band alone buys: groups skipped, bytes decoded, and wall clock against
the unpruned (eager: read-everything-then-mask) baseline — with bitwise
parity asserted at every point, for the lone ``variants`` verb and for a
fused 4-verb ``collect_many`` that includes it.

``--smoke`` asserts the acceptance gates: pruned == unpruned bitwise,
skip ratio > 0 at every selectivity, and < 25% of the bytes decoded at
the ~1% point (fused collection included).

Writes the ``BENCH_variants.json`` trajectory artifact.

Standalone:  python benchmarks/bench_variants_prune.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only variants_prune
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np

SELECTIVITIES = (0.01, 0.10, 0.50)
FUSED_VERBS = ("dfg", "stats", "variants", "heuristics")


def _variant_census(frame):
    """[(fingerprint, case_count)] sorted most-frequent-first."""
    from repro.core import ACTIVITY, CASE
    from repro.core.polyhash import sequence_fingerprint

    case = np.asarray(frame[CASE])
    act = np.asarray(frame[ACTIVITY])
    seqs: dict = {}
    for c, a in zip(case.tolist(), act.tolist()):
        seqs.setdefault(c, []).append(a)
    census: dict = {}
    for seq in seqs.values():
        fp = sequence_fingerprint(seq)
        census[fp] = census.get(fp, 0) + 1
    return sorted(census.items(), key=lambda kv: -kv[1])


def _band_for(census, num_cases, target):
    """Greedy fingerprint band covering ~``target`` of the cases."""
    want = max(1, int(num_cases * target))
    band, covered = [], 0
    for fp, cnt in census:
        if covered >= want:
            break
        if covered + cnt <= max(want, covered + 1):
            band.append(fp)
            covered += cnt
    return band, covered


def _tree_equal(a, b):
    import dataclasses

    import jax

    if isinstance(a, (jax.Array, np.ndarray)):
        return bool((np.asarray(a) == np.asarray(b)).all())
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        return type(a) is type(b) and all(
            _tree_equal(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, dict):
        return set(a) == set(b) and all(_tree_equal(a[k], b[k]) for k in a)
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(
            _tree_equal(x, y) for x, y in zip(a, b))
    return a == b


def run(num_cases: int = 50_000, num_activities: int = 12, seed: int = 47,
        num_files: int = 4, cases_per_group: int = 8,
        out_json: str | None = "BENCH_variants.json", smoke: bool = False):
    import jax
    import jax.numpy as jnp

    import repro
    from repro.core import CASE
    from repro.data import synthetic
    from repro.query import variant_in
    from repro.storage import edf

    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=num_activities,
                                       seed=seed)
    n = frame.nrows
    census = _variant_census(frame)
    emit("variants_prune/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n};variants={len(census)}")

    d = tempfile.mkdtemp()
    case = np.asarray(frame[CASE])
    paths = []
    per = -(-num_cases // num_files)
    for m in range(num_files):
        lo = int(np.searchsorted(case, m * per))
        hi = int(np.searchsorted(case, (m + 1) * per))
        if lo == hi:
            continue
        p = os.path.join(d, f"part_{m:02d}.edf")
        # band keeps are scattered over the case axis (unlike a CASE-range
        # predicate), so pruning granularity == group granularity: size
        # groups in *cases*, not a fixed row count
        ncases_here = len(np.unique(case[lo:hi]))
        rows = max(1, (hi - lo) * cases_per_group // max(ncases_here, 1))
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables, codec="zlib1",
                  row_group_rows=rows)
        paths.append(p)
    total_bytes = sum(os.path.getsize(p) for p in paths)
    emit("variants_prune/write_partitions", 0.0,
         f"files={len(paths)};bytes={total_bytes}")

    base = repro.open(paths)
    points = []
    for sel in SELECTIVITIES:
        band, covered = _band_for(census, num_cases, sel)
        ds = base.filter(variant_in(band))

        pruned = ds.collect("variants", engine="streaming")
        us_pruned = timeit(lambda: ds.collect("variants",
                                              engine="streaming"))
        unpruned = ds.collect("variants", engine="eager")
        us_unpruned = timeit(lambda: ds.collect("variants", engine="eager"))
        assert _tree_equal(tuple(pruned.result), tuple(unpruned.result)), \
            f"pruned != unpruned at sel={sel}"
        rep = pruned.report
        assert rep.groups_skipped > 0, f"no groups skipped at sel={sel}"
        assert rep.phase1_groups_read == 0, \
            "variant band paid a phase-one pass (want header-only keeps)"

        point = {
            "selectivity_target": sel,
            "selectivity_actual": covered / num_cases,
            "band_size": len(band),
            "groups_total": rep.groups_total,
            "groups_skipped": rep.groups_skipped,
            "bytes_total": rep.bytes_total,
            "bytes_read": rep.bytes_read,
            "bytes_fraction": rep.bytes_read / max(rep.bytes_total, 1),
            "us_pruned": us_pruned * 1e6,
            "us_unpruned": us_unpruned * 1e6,
            "speedup": us_unpruned / max(us_pruned, 1e-9),
        }
        points.append(point)
        emit(f"variants_prune/sel={sel}", us_pruned,
             f"skip={rep.groups_skipped}/{rep.groups_total};"
             f"bytes={rep.bytes_read}/{rep.bytes_total};"
             f"speedup={point['speedup']:.2f}x")

    # fused 4-verb collection at the tightest band: pruning must survive
    # variants riding along with every other verb
    band, covered = _band_for(census, num_cases, SELECTIVITIES[0])
    ds = base.filter(variant_in(band))
    fused = ds.collect_many(FUSED_VERBS, engine="streaming")
    us_fused = timeit(lambda: ds.collect_many(FUSED_VERBS,
                                              engine="streaming"))
    for v in FUSED_VERBS:
        ref = ds.collect(v, engine="eager").result
        assert _tree_equal(fused[v], ref), f"fused {v} != eager"
    frep = fused.report
    assert frep.groups_skipped > 0, "fused collection lost pruning"
    fused_point = {
        "verbs": list(FUSED_VERBS),
        "selectivity_actual": covered / num_cases,
        "groups_skipped": frep.groups_skipped,
        "groups_total": frep.groups_total,
        "bytes_read": frep.bytes_read,
        "bytes_total": frep.bytes_total,
        "bytes_fraction": frep.bytes_read / max(frep.bytes_total, 1),
        "us_fused": us_fused * 1e6,
    }
    emit("variants_prune/fused_4verbs", us_fused,
         f"skip={frep.groups_skipped}/{frep.groups_total};"
         f"bytes={frep.bytes_read}/{frep.bytes_total}")

    if smoke:
        tight = points[0]
        assert tight["bytes_fraction"] < 0.25, \
            (f"1% band decoded {tight['bytes_fraction']:.0%} of the bytes "
             f"(want < 25%)")
        assert fused_point["bytes_fraction"] < 0.25, \
            (f"fused 4-verb 1% band decoded "
             f"{fused_point['bytes_fraction']:.0%} of the bytes (want < 25%)")

    if out_json:
        artifact = {
            "bench": "variants_prune",
            "jax": jax.__version__,
            "python": platform.python_version(),
            "backend": jax.default_backend(),
            "config": {"num_cases": num_cases,
                       "num_activities": num_activities, "events": n,
                       "files": len(paths), "bytes_total": total_bytes,
                       "distinct_variants": len(census)},
            "selectivity_sweep": points,
            "fused_collection": fused_point,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"variants_prune/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return points


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; asserts parity + <25% bytes at 1%")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_variants.json")
    args = ap.parse_args()
    header()
    cases = 200_000 if args.full else (15_000 if args.smoke else 50_000)
    points = run(num_cases=cases, out_json=args.out, smoke=args.smoke)
    if args.smoke:
        print(f"variants_prune/SMOKE_OK,0.0,bytes_fraction="
              f"{points[0]['bytes_fraction']:.3f}", flush=True)


if __name__ == "__main__":
    main()
