"""Sliding-window benchmark: slide cost scales with delta groups.

Windows over a multi-file log are re-merges of cached per-group states,
so once the cache is warm a slide decodes nothing — wall clock tracks
the number of *fresh* groups (the delta), not the window size.  The
sweep times windowed DFG collection cold (every group decoded once) and
warm (pure merge) across growing window sizes, then replays the
incremental scenario: append one partition and re-collect, proving via
``ScanReport`` that only the appended groups are read.

``--smoke`` asserts the acceptance gates: warm windows bitwise equal to
cold ones, warm cache-hit ratio > 0, and the post-append collect reading
only the delta groups.

Writes the ``BENCH_window.json`` trajectory artifact.

Standalone:  python benchmarks/bench_window.py [--smoke | --full]
Harness:     PYTHONPATH=src python -m benchmarks.run --only window
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import tempfile
import time

if __package__ in (None, ""):  # script mode
    _here = os.path.dirname(os.path.abspath(__file__))
    sys.path.insert(0, _here)
    sys.path.insert(0, os.path.join(_here, "..", "src"))
    from common import emit, header, timeit
else:
    from .common import emit, header, timeit

import numpy as np

WINDOW_SIZES = (2, 4, 8, 16)
STEP = 2


def _tree_equal(a, b):
    import dataclasses

    import jax

    if isinstance(a, (jax.Array, np.ndarray)):
        return bool((np.asarray(a) == np.asarray(b)).all())
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        return type(a) is type(b) and all(
            _tree_equal(getattr(a, f.name), getattr(b, f.name))
            for f in dataclasses.fields(a))
    if isinstance(a, dict):
        return set(a) == set(b) and all(_tree_equal(a[k], b[k]) for k in a)
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(
            _tree_equal(x, y) for x, y in zip(a, b))
    return a == b


def run(num_cases: int = 50_000, num_activities: int = 8, seed: int = 11,
        num_files: int = 4, groups_per_file: int = 12,
        out_json: str | None = "BENCH_window.json", smoke: bool = False):
    import jax
    import jax.numpy as jnp

    import repro
    from repro.data import synthetic
    from repro.dataset import engines as ds_engines
    from repro.query.statecache import state_cache
    from repro.storage import edf

    t0 = time.perf_counter()
    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=num_activities,
                                       seed=seed)
    n = frame.nrows
    emit("window/generate", time.perf_counter() - t0,
         f"cases={num_cases};events={n}")

    d = tempfile.mkdtemp()
    paths = []
    per = -(-n // num_files)
    for m in range(num_files):
        lo, hi = m * per, min((m + 1) * per, n)
        if lo >= hi:
            continue
        p = os.path.join(d, f"part_{m:02d}.edf")
        edf.write(p, frame.take(jnp.arange(lo, hi)), tables,
                  row_group_rows=max(1, -(-(hi - lo) // groups_per_file)))
        paths.append(p)
    hints = {"num_activities": num_activities, "num_cases": num_cases}
    base, delta = paths[:-1], paths[-1]

    def fresh():
        state_cache().clear()
        ds_engines.clear_result_cache()

    # ---- window-size sweep: cold decode-all vs warm merge-only slides
    ds = repro.open(paths, **hints)
    n_units = ds.window(by="groups", size=1, step=1)._num_units()
    sweep = []
    for size in WINDOW_SIZES:
        if size > n_units:
            break
        w = ds.window(by="groups", size=size, step=STEP)
        fresh()
        t0 = time.perf_counter()
        cold = w.collect("dfg")
        us_cold = time.perf_counter() - t0
        warm = w.collect("dfg")
        us_warm = timeit(lambda: w.collect("dfg"))
        assert _tree_equal(cold.results, warm.results), \
            f"warm windows != cold at size={size}"
        rep = warm.report
        hit_ratio = rep.groups_cached / max(
            rep.groups_cached + rep.groups_folded, 1)
        assert rep.groups_read == 0, "warm slide decoded a group"
        nw = len(cold.bounds)
        point = {
            "window_size": size,
            "step": STEP,
            "windows": nw,
            "groups_total": cold.report.groups_total,
            "us_cold": us_cold * 1e6,
            "us_warm": us_warm * 1e6,
            "us_warm_per_window": us_warm * 1e6 / max(nw, 1),
            "warm_hit_ratio": hit_ratio,
        }
        sweep.append(point)
        emit(f"window/size={size}", us_warm,
             f"windows={nw};cold_us={us_cold*1e6:.0f};"
             f"hit={hit_ratio:.2f};speedup={us_cold/max(us_warm,1e-9):.1f}x")

    # ---- incremental append: re-collect reads only the delta groups
    fresh()
    ds_base = repro.open(base, **hints)
    t0 = time.perf_counter()
    r_base = ds_base.collect("dfg", engine="streaming")
    us_base = time.perf_counter() - t0
    ds_engines.clear_result_cache()
    ds_all = repro.open(paths, **hints)
    t0 = time.perf_counter()
    r_incr = ds_all.collect("dfg", engine="streaming")
    us_incr = time.perf_counter() - t0
    delta_groups = r_incr.report.groups_total - r_base.report.groups_folded
    assert r_incr.report.groups_read == delta_groups, \
        "incremental collect decoded non-delta groups"
    fresh()
    t0 = time.perf_counter()
    r_scratch = repro.open(paths, **hints).collect("dfg", engine="eager")
    us_scratch = time.perf_counter() - t0
    assert _tree_equal(r_incr.result, r_scratch.result), \
        "incremental != scratch"
    append_point = {
        "base_groups": r_base.report.groups_folded,
        "delta_groups": delta_groups,
        "groups_read_incremental": r_incr.report.groups_read,
        "groups_cached_incremental": r_incr.report.groups_cached,
        "us_base_cold": us_base * 1e6,
        "us_incremental": us_incr * 1e6,
        "us_scratch": us_scratch * 1e6,
        "speedup_vs_scratch": us_scratch / max(us_incr, 1e-9),
    }
    emit("window/append_delta", us_incr,
         f"read={r_incr.report.groups_read}/{r_incr.report.groups_total};"
         f"cached={r_incr.report.groups_cached};"
         f"scratch_speedup={append_point['speedup_vs_scratch']:.1f}x")

    if smoke:
        assert all(p["warm_hit_ratio"] > 0 for p in sweep), \
            "warm slides never hit the state cache"
        assert delta_groups < r_incr.report.groups_total, \
            "append scenario had no cached base groups"

    if out_json:
        artifact = {
            "bench": "window",
            "jax": jax.__version__,
            "python": platform.python_version(),
            "backend": jax.default_backend(),
            "config": {"num_cases": num_cases,
                       "num_activities": num_activities, "events": n,
                       "files": len(paths), "group_units": n_units},
            "size_sweep": sweep,
            "append": append_point,
        }
        with open(out_json, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"window/ARTIFACT,0.0,wrote={out_json}", flush=True)
    return sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; asserts parity + warm hit ratio > 0")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_window.json")
    args = ap.parse_args()
    header()
    cases = 200_000 if args.full else (8_000 if args.smoke else 50_000)
    sweep = run(num_cases=cases, out_json=args.out, smoke=args.smoke)
    if args.smoke:
        print(f"window/SMOKE_OK,0.0,hit_ratio="
              f"{sweep[-1]['warm_hit_ratio']:.2f}", flush=True)


if __name__ == "__main__":
    main()
