"""Distributed process mining: shard_map DFG + all-to-all distributed sort.

Runs itself in a child process with 8 virtual host devices (the XLA flag
must be set before jax initializes), computes the DFG of a 1.4M-event log
sharded 8 ways, validates against the single-device result, and shows the
distributed sort-by-case that the shifting strategy assumes.

  PYTHONPATH=src python examples/distributed_mining.py
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(HERE, "..", "src")

CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax
import jax.numpy as jnp
import numpy as np
from repro.core import dfg
from repro.core.eventframe import ACTIVITY, CASE, EventFrame
from repro.data import synthetic
from repro.distributed.dfg import dfg_sharded_host
from repro.distributed.sort import sort_by_case_sharded

print(f"devices: {len(jax.devices())}")
frame, tables = synthetic.generate(num_cases=200_000, num_activities=26, seed=5)
n = frame.nrows
pad = (-n) % 8
cols = {k: jnp.pad(v, (0, pad), constant_values=-1) for k, v in frame.columns.items()}
frame = EventFrame(cols, {}, jnp.pad(frame.rows_valid(), (0, pad)))
print(f"log: {n:,} events, sharded 8 ways")

ref = np.asarray(dfg(frame, 26, method="segment").counts)
t0 = time.time(); local = np.asarray(dfg(frame, 26, method="segment").counts)
t_local = time.time() - t0
t0 = time.time(); got = np.asarray(dfg_sharded_host(frame, 26, 8).counts)
t_dist = time.time() - t0
assert (got == ref).all(), "distributed DFG mismatch!"
print(f"DFG single-device: {t_local*1e3:.1f}ms   sharded x8 (map+psum): "
      f"{t_dist*1e3:.1f}ms   counts identical: True")
print(f"reduce payload: one {26}x{26} int32 psum = {26*26*4} bytes "
      f"(vs a Spark shuffle of O(N) edges)")

# distributed sort: scramble event order, re-sort by case via all_to_all
perm = np.random.default_rng(0).permutation(frame.nrows)
scrambled = frame.take(jnp.asarray(perm))
mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
t0 = time.time()
case_s, act_s, ts_s, overflow = sort_by_case_sharded(scrambled, mesh)
jax.block_until_ready(case_s)
print(f"distributed sort-by-case (bucket all_to_all + local lexsort): "
      f"{(time.time()-t0)*1e3:.1f}ms, bucket overflow: {bool(overflow)}")
case_np = np.asarray(case_s).reshape(8, -1)   # one row per shard
ok = all(bool((np.diff(row[row >= 0]) >= 0).all()) for row in case_np)
owners = {int(c) % 8 for row in case_np for c in np.unique(row[row >= 0])[:50]}
print(f"each shard case-sorted: {ok}; cases land on hash(case)%8 shard: "
      f"{all((np.unique(row[row>=0]) % 8 == i).all() for i, row in enumerate(case_np))}")
"""


def main():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    res = subprocess.run([sys.executable, "-c", CHILD], env=env, text=True)
    raise SystemExit(res.returncode)


if __name__ == "__main__":
    main()
