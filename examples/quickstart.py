"""Quickstart: the paper's pipeline end to end on one page — Dataset API.

generate log -> columnar EDF (Parquet role) -> repro.open() -> fluent
filters (pushed down to zone maps: cold row groups are never read) ->
DFG / stats / alpha miner / heuristics miner / conformance replay, each a
terminal verb that compiles to the same chunk-kernel engine whatever the
execution engine (eager | streaming | sharded | auto).

  PYTHONPATH=src python examples/quickstart.py [--cases N]
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro
from repro import col
from repro.core import ACTIVITY, CASE, conformance
from repro.data import synthetic
from repro.storage import edf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=100_000)
    args = ap.parse_args()

    t0 = time.time()
    frame, tables = synthetic.generate(num_cases=args.cases,
                                       num_activities=12, seed=0)
    print(f"generated {frame.nrows:,} events / {args.cases:,} cases "
          f"in {time.time()-t0:.2f}s")

    d = tempfile.mkdtemp()
    path = os.path.join(d, "log.edf")
    edf.write(path, frame, tables, codec="zlib1",
              row_group_rows=max(1, frame.nrows // 24))
    print(f"EDF on disk: {os.path.getsize(path)/2**20:.1f} MiB "
          f"({edf.file_sizes(path)['raw']/2**20:.1f} MiB raw, "
          f"{edf.num_row_groups(path)} row groups + zone maps)")

    # one fluent facade over every engine ------------------------------
    ds = repro.open(path)
    acts = ds.tables[ACTIVITY]

    t0 = time.time()
    graph = ds.dfg()                       # engine picked by cost (auto)
    graph.counts.block_until_ready()
    print(f"DFG in {time.time()-t0:.3f}s: {len(graph.edges())} edges, "
          f"{int(graph.counts.sum()):,} df-pairs")
    for (a, b), c in sorted(graph.edges(), key=lambda e: -e[1])[:5]:
        print(f"   {acts[a]:>8s} -> {acts[b]:<8s} x{c:,}")

    model = conformance.discover_model(graph, noise_threshold=0.05)
    fit = conformance.footprint_fitness(graph, model)
    print(f"discovered model (IMDF-style 5% noise cut): fitness {float(fit):.3f}")

    # alpha + heuristics miners: terminal verbs over the same state
    t0 = time.time()
    alpha_model = ds.alpha()
    net = ds.heuristics()
    print(f"alpha miner in {time.time()-t0:.3f}s: {alpha_model.num_places} "
          f"places, starts={sorted(acts[i] for i in alpha_model.start_activities)}")
    n_edges = int(np.asarray(net.graph).sum())
    print(f"heuristics miner: {n_edges} dependency edges, "
          f"fitness {float(ds.conformance(net)):.3f}, "
          f"alpha conformance {float(ds.conformance(alpha_model)):.3f}")

    # pushdown filters: the zone maps decide which row groups to read
    # BEFORE any I/O — same bitwise DFG, a fraction of the bytes
    lo, hi = args.cases // 10, args.cases // 10 + args.cases // 20
    sel = ds.filter(col(CASE).between(lo, hi)).project([CASE, ACTIVITY])
    t0 = time.time()
    r = sel.collect("dfg", engine="streaming")
    print(f"pushdown query in {time.time()-t0:.3f}s: skipped "
          f"{r.report.groups_skipped}/{r.report.groups_total} row groups, "
          f"read {r.report.bytes_read/2**10:.0f} KiB of "
          f"{r.report.bytes_total/2**10:.0f} KiB "
          f"-> {int(r.result.counts.sum()):,} df-pairs "
          f"(bitwise == filter-then-mine)")

    # the cost model explains itself
    print(sel.explain("dfg"))

    top = int(np.argmax(np.asarray(ds.collect("activity_counts").result)))
    kept = ds.filter(col(ACTIVITY) == top).to_frame()
    print(f"filter most-common activity ({acts[top]}): "
          f"{kept.nrows:,} events kept")


if __name__ == "__main__":
    main()
