"""Quickstart: the paper's pipeline end to end on one page.

generate log -> columnar EDF (Parquet role) -> load 2 columns -> filter ->
DFG (shifting-and-counting, Fig. 3) -> discover model -> conformance.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ACTIVITY, CASE, conformance, dfg, filtering
from repro.data import synthetic
from repro.storage import edf


def main():
    t0 = time.time()
    frame, tables = synthetic.generate(num_cases=100_000, num_activities=12, seed=0)
    print(f"generated {frame.nrows:,} events / 100k cases in {time.time()-t0:.2f}s")

    d = tempfile.mkdtemp()
    path = os.path.join(d, "log.edf")
    edf.write(path, frame, tables, codec="zlib1")
    print(f"EDF on disk: {os.path.getsize(path)/2**20:.1f} MiB "
          f"({edf.file_sizes(path)['raw']/2**20:.1f} MiB raw)")

    t0 = time.time()
    frame2, tables2 = edf.read(path, columns=[CASE, ACTIVITY])
    print(f"loaded case+activity columns in {time.time()-t0:.3f}s "
          f"(column projection — paper Fig. 1)")

    acts = tables2[ACTIVITY]
    t0 = time.time()
    graph = dfg(frame2, len(acts), method="shift")
    graph.counts.block_until_ready()
    print(f"DFG (shift-and-count) in {time.time()-t0:.3f}s: "
          f"{len(graph.edges())} edges, {int(graph.counts.sum()):,} df-pairs")
    top = sorted(graph.edges(), key=lambda e: -e[1])[:5]
    for (a, b), c in top:
        print(f"   {acts[a]:>8s} -> {acts[b]:<8s} x{c:,}")

    model = conformance.discover_model(graph, noise_threshold=0.05)
    fit = conformance.footprint_fitness(graph, model)
    print(f"discovered model (IMDF-style 5% noise cut): fitness {float(fit):.3f}")

    top_act = int(filtering.most_common_activity(frame2, len(acts)))
    filtered = filtering.filter_attr_values(frame2, ACTIVITY, [top_act])
    print(f"filter most-common activity ({acts[top_act]}): "
          f"{int(filtered.rows_valid().sum()):,} events kept")


if __name__ == "__main__":
    main()
