"""Quickstart: the paper's pipeline end to end on one page.

generate log -> columnar EDF (Parquet role) -> load 2 columns -> filter ->
DFG (shifting-and-counting, Fig. 3) -> discover models (IMDF-style cut,
alpha miner, heuristics miner — all finalize steps of the same columnar
state) -> conformance replay -> lazy pushdown query (zone maps skip row
groups before any I/O).

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ACTIVITY, CASE, conformance, dfg, discovery, filtering
from repro.data import synthetic
from repro.storage import edf


def main():
    t0 = time.time()
    frame, tables = synthetic.generate(num_cases=100_000, num_activities=12, seed=0)
    print(f"generated {frame.nrows:,} events / 100k cases in {time.time()-t0:.2f}s")

    d = tempfile.mkdtemp()
    path = os.path.join(d, "log.edf")
    edf.write(path, frame, tables, codec="zlib1")
    print(f"EDF on disk: {os.path.getsize(path)/2**20:.1f} MiB "
          f"({edf.file_sizes(path)['raw']/2**20:.1f} MiB raw)")

    t0 = time.time()
    frame2, tables2 = edf.read(path, columns=[CASE, ACTIVITY])
    print(f"loaded case+activity columns in {time.time()-t0:.3f}s "
          f"(column projection — paper Fig. 1)")

    acts = tables2[ACTIVITY]
    t0 = time.time()
    graph = dfg(frame2, len(acts), method="shift")
    graph.counts.block_until_ready()
    print(f"DFG (shift-and-count) in {time.time()-t0:.3f}s: "
          f"{len(graph.edges())} edges, {int(graph.counts.sum()):,} df-pairs")
    top = sorted(graph.edges(), key=lambda e: -e[1])[:5]
    for (a, b), c in top:
        print(f"   {acts[a]:>8s} -> {acts[b]:<8s} x{c:,}")

    model = conformance.discover_model(graph, noise_threshold=0.05)
    fit = conformance.footprint_fitness(graph, model)
    print(f"discovered model (IMDF-style 5% noise cut): fitness {float(fit):.3f}")

    # alpha + heuristics miners: pure finalize over the columnar state
    # (case + activity columns suffice — the same projected load as the DFG)
    t0 = time.time()
    state = discovery.discovery_state(frame2, len(acts))
    alpha_model = discovery.discover_alpha(state.dfg)
    net = discovery.discover_heuristics(state)
    print(f"alpha miner in {time.time()-t0:.3f}s: {alpha_model.num_places} "
          f"places, starts={sorted(acts[i] for i in alpha_model.start_activities)}")
    n_edges = int(np.asarray(net.graph).sum())
    print(f"heuristics miner: {n_edges} dependency edges, "
          f"fitness {float(conformance.heuristics_fitness(state.dfg, net)):.3f}, "
          f"footprint conformance "
          f"{float(conformance.footprint_conformance(state.dfg, alpha_model)):.3f}")

    top_act = int(filtering.most_common_activity(frame2, len(acts)))
    filtered = filtering.filter_attr_values(frame2, ACTIVITY, [top_act])
    print(f"filter most-common activity ({acts[top_act]}): "
          f"{int(filtered.rows_valid().sum()):,} events kept")

    # lazy pushdown query: the plan's zone maps decide which row groups to
    # read BEFORE any I/O — same DFG, a fraction of the bytes
    path3 = os.path.join(d, "log_v3.edf")
    edf.write(path3, frame, tables, codec="zlib1",
              row_group_rows=frame.nrows // 24)
    from repro.core.dfg import dfg_kernel
    from repro.query import scan, col, execute

    plan = (scan(path3)
            .filter(col(CASE).between(10_000, 15_000))
            .project([CASE, ACTIVITY]))
    t0 = time.time()
    pruned, report = execute(plan, mine=dfg_kernel(len(acts)))
    print(f"pushdown query in {time.time()-t0:.3f}s: skipped "
          f"{report.groups_skipped}/{report.groups_total} row groups, read "
          f"{report.bytes_read/2**10:.0f} KiB of {report.bytes_total/2**10:.0f} KiB "
          f"-> {int(pruned.counts.sum()):,} df-pairs (bitwise == filter-then-mine)")


if __name__ == "__main__":
    main()
