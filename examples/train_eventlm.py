"""End-to-end driver: train EventLM on next-activity prediction.

The EventFrame pipeline feeds packed case sequences into the LM; training
runs with checkpointing, auto-resume and failure injection — the same loop
the multi-pod launcher uses, scaled to CPU.

  # quick (reduced ~1M params, ~1 min):
  PYTHONPATH=src python examples/train_eventlm.py
  # full 100M-param run, a few hundred steps (hours on CPU, minutes on TPU):
  PYTHONPATH=src python examples/train_eventlm.py --full --steps 300
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full eventlm-100m config instead of the reduced one")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step to demo restart")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="eventlm_ckpt_")
    argv = ["--arch", "eventlm-100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", ckpt,
            "--ckpt-every", "25"]
    if not args.full:
        argv.append("--reduced")

    if args.fail_at is not None:
        # first run dies at --fail-at; second run auto-resumes from the
        # latest checkpoint — the multi-pod restart story on one host.
        try:
            T.main(argv + ["--fail-at", str(args.fail_at)])
        except RuntimeError as e:
            print(f"[example] {e} -> restarting from checkpoint")
        T.main(argv + ["--resume"])
    else:
        T.main(argv)
    print(f"[example] checkpoints kept in {ckpt}")


if __name__ == "__main__":
    main()
