"""Multi-log dashboard demo: interactive filter queries over a file *set*.

The serving-layer scenario the Dataset facade was built for: an event log
partitioned into monthly EDF files (cases never re-open across months),
queried interactively — every dashboard widget is a fluent filter + verb,
and the zone maps make sure a widget scoped to one month (or one org
region, one case band) never reads the cold months' bytes.

  PYTHONPATH=src python examples/dashboard.py [--cases N] [--months M]
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro
from repro import cases_containing, col
from repro.core import ACTIVITY, CASE

REGION = "org:region"          # an extra dictionary attribute per event


def build_monthly_logs(num_cases: int, months: int, tmpdir: str):
    """One synthetic sorted log, written as consecutive monthly files."""
    import jax.numpy as jnp

    from repro.data import synthetic
    from repro.storage import edf

    frame, tables = synthetic.generate(num_cases=num_cases,
                                       num_activities=10, seed=42)
    # tag every event with a region drawn per case (east/west/north/south)
    rng = np.random.default_rng(7)
    case = np.asarray(frame[CASE])
    per_case = rng.integers(0, 4, size=num_cases)
    frame = frame.with_column(REGION, jnp.asarray(per_case[case].astype(np.int32)))
    tables = dict(tables, **{REGION: ["east", "west", "north", "south"]})

    paths = []
    cases_per_month = -(-num_cases // months)
    for m in range(months):
        lo = int(np.searchsorted(case, m * cases_per_month))
        hi = int(np.searchsorted(case, (m + 1) * cases_per_month))
        if lo == hi:
            continue
        p = os.path.join(tmpdir, f"month_{m:02d}.edf")
        part = frame.take(jnp.arange(lo, hi))
        edf.write(p, part, tables, codec="zlib1",
                  row_group_rows=max(1, (hi - lo) // 8))
        paths.append(p)
    return paths, tables


def widget(title: str, ds, verb: str = "dfg", **kwargs):
    """One dashboard panel: run a verb, report latency + bytes touched."""
    t0 = time.time()
    r = ds.collect(verb, **kwargs)
    dt = time.time() - t0
    if r.report is not None:
        io = (f"{r.report.bytes_read/2**10:.0f}/"
              f"{r.report.bytes_total/2**10:.0f} KiB, "
              f"{r.report.groups_skipped}/{r.report.groups_total} groups "
              f"skipped")
    else:
        io = "in-memory"
    print(f"  {title:<44s} {dt*1e3:7.1f} ms  [{r.engine:>9s}] {io}")
    return r.result


def fused_panel(title: str, ds, verbs, **kwargs):
    """A whole panel *group* in one pass: ``collect_many`` fuses the verbs
    into a single kernel over a single scan, so the refresh costs one
    read of the union of the verbs' columns instead of one scan each."""
    t0 = time.time()
    r = ds.collect_many(verbs, **kwargs)
    dt = time.time() - t0
    if r.report is not None:
        io = (f"{r.report.bytes_read/2**10:.0f}/"
              f"{r.report.bytes_total/2**10:.0f} KiB, "
              f"prefetch {r.report.prefetch}")
    else:
        io = "in-memory"
    print(f"  {title:<44s} {dt*1e3:7.1f} ms  [{r.engine:>9s}] {io}")
    print(f"    one scan -> {', '.join(r.verbs)}")
    return r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=60_000)
    ap.add_argument("--months", type=int, default=6)
    args = ap.parse_args()

    d = tempfile.mkdtemp()
    t0 = time.time()
    paths, tables = build_monthly_logs(args.cases, args.months, d)
    total = sum(os.path.getsize(p) for p in paths)
    print(f"{len(paths)} monthly files, {total/2**20:.1f} MiB total "
          f"(built in {time.time()-t0:.1f}s)")

    ds = repro.open(paths)                 # the whole year, one dataset
    acts = ds.tables[ACTIVITY]
    region = ds.tables[REGION]

    print(f"\ndashboard over {args.cases:,} cases / {len(paths)} logs "
          f"(every result bitwise == filter-then-mine):")

    # the landing page: DFG + stats + performance + an alpha model — four
    # widgets, ONE fused kernel, ONE scan of the year (previously four)
    landing = fused_panel("whole-year landing page (4 verbs, 1 scan)", ds,
                          ["dfg", "stats", "performance_dfg", "alpha"])
    sizes = np.asarray(landing["stats"]["case_sizes"])
    print(f"    busiest edge x{int(np.asarray(landing['dfg'].counts).max())}"
          f", {int((sizes > 0).sum())} cases, "
          f"{len(landing['alpha'].places)} alpha places")

    east = region.index("east")
    widget(f'region == "east" DFG', ds.filter(col(REGION) == east), "dfg")

    month_cases = -(-args.cases // args.months)
    one_month = ds.filter(col(CASE).between(2 * month_cases,
                                            3 * month_cases - 1))
    widget("one month's case band (cold months unread)", one_month, "dfg",
           engine="streaming")

    widget(f'cases containing "{acts[4]}" -> heuristics net',
           ds.filter(cases_containing(4)), "heuristics")

    sel = one_month.filter(col(REGION) == east)
    r = sel.collect("dfg", engine="streaming")
    frac = r.report.bytes_read / max(r.report.bytes_total, 1)
    widget("month x region drill-down", sel, "dfg", engine="streaming")
    print(f"\ndrill-down read {100*frac:.1f}% of the dataset's bytes "
          f"({r.report.groups_skipped}/{r.report.groups_total} row groups "
          f"skipped before any I/O)")

    # the monitoring strip: a sliding window re-merges cached per-group
    # states, so after the first refresh a slide decodes nothing — and
    # drift scores each window's DFG against the previous one
    n_units = ds.window(by="groups", size=1)._num_units()
    size = max(2, n_units // len(paths) * 2)          # ~two months wide
    w = ds.window(by="groups", size=size, step=max(1, size // 2))
    t0 = time.time()
    wm = w.collect_many(["dfg", "activity_counts"])
    cold_ms = (time.time() - t0) * 1e3
    t0 = time.time()
    w.collect_many(["dfg", "activity_counts"])
    warm_ms = (time.time() - t0) * 1e3
    scores = w.drift()
    print(f"\nsliding-window strip ({len(wm.bounds)} windows of {size} "
          f"row groups, step {max(1, size // 2)}):")
    print(f"  first refresh {cold_ms:7.1f} ms (decodes each group once), "
          f"slide {warm_ms:7.1f} ms (pure re-merge)")
    for (lo, hi), drift_w, res in zip(wm.bounds, scores, wm.results):
        busiest = int(np.asarray(res["dfg"].counts).max())
        bar = "#" * int(round(20 * drift_w))
        print(f"  groups [{lo:2d},{hi:2d})  drift {drift_w:5.3f} {bar:<20s}"
              f" busiest edge x{busiest}")

    # the bottleneck panel: compile the year's merged DFG state into the
    # weighted process graph and ask for its widest start -> end corridor
    # (max-min semiring closure over the frequency weights) — the path
    # every throughput fix has to widen, and the edge that throttles it
    t0 = time.time()
    g = ds.graph()
    bp = ds.bottlenecks()
    dt = (time.time() - t0) * 1e3
    labels = g.node_labels()
    freq = np.asarray(g.freq)
    print(f"\nbottleneck corridor ({g.num_nodes}-node graph, {dt:.1f} ms):")
    hops = list(zip(bp.path[:-1], bp.path[1:]))
    print("  " + " -> ".join(labels[i] for i in bp.path))
    print("  edge flows: " +
          ", ".join(f"{labels[a]}->{labels[b]} x{freq[a, b]}"
                    for a, b in hops))
    print(f"  throttled at x{bp.bottleneck:.0f} "
          f"(rarest edge on the widest start->end path)")

    print("\nexplain (the fused landing-page plan):")
    print(ds.explain(verbs=["dfg", "stats", "performance_dfg", "alpha"]))


if __name__ == "__main__":
    main()
