"""Serve an event LM: batched prefill + KV-cache decode.

Trains a small model briefly on synthetic process logs, then serves batched
"what happens next?" queries — greedy continuations of running cases.

  PYTHONPATH=src python examples/serve_eventlm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.eventframe import ACTIVITY
from repro.data import pipeline, synthetic, tokenizer
from repro.launch import train as T
from repro.models import model as Mdl
from repro.models.module import Initializer
from repro.serve.engine import Engine
from repro.train import trainstep as TS
from repro.train.optimizer import OptConfig


def main():
    cfg = reduced_config(get_config("eventlm-100m")).with_overrides(vocab_size=128)
    frame, tables = synthetic.generate(num_cases=30_000, num_activities=20, seed=1)
    tok = tokenizer.ActivityTokenizer(tables[ACTIVITY])

    # short training run so predictions beat chance
    params = Mdl.init_params(cfg, Initializer(jax.random.PRNGKey(0)))
    state = TS.init_state(cfg, params)
    rules = T.local_rules()
    step = jax.jit(TS.make_train_step(cfg, rules, OptConfig(total_steps=150), 1),
                   donate_argnums=(0,))
    stream = pipeline.frame_to_token_stream(frame, tok)
    it = pipeline.batches(stream, 8, 128)
    for i in range(150):
        b = next(it)
        state, m = step(state, {"tokens": b.tokens, "targets": b.targets,
                                "loss_mask": b.loss_mask})
        if i % 50 == 0:
            print(f"[serve-example] warmup train step {i} loss {float(m['loss']):.3f}")

    engine = Engine(cfg, state["params"], max_len=64)
    # batched requests: prefixes of real cases
    prompts = np.stack([stream[i * 40:i * 40 + 12] for i in range(8)])
    t0 = time.time()
    out = engine.generate(prompts, steps=8)
    dt = time.time() - t0
    print(f"[serve-example] 8 requests x 8 tokens in {dt:.2f}s "
          f"({8 * 8 / dt:.1f} tok/s incl. prefill)")
    for r in range(3):
        ctx = " ".join(tok.decode(prompts[r])[-4:])
        cont = " ".join(tok.decode(out.tokens[r]))
        print(f"  case {r}: ...{ctx}  =>  {cont}")


if __name__ == "__main__":
    main()
