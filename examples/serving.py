"""Live mining service demo: ingest an event stream while clients query.

The end-to-end serving loop: a producer drops batch files into a spool
directory, an :class:`Ingestor` tails them into partitioned EDFV0003
files (atomic appends, crash-safe skip-index), and an HTTP JSON API
answers mining queries concurrently — every response carrying the exact
snapshot it was mined from, with the per-group state cache keeping
post-append re-collects incremental.

  PYTHONPATH=src python examples/serving.py [--cases N] [--batches B]
                                            [--port P]
"""
import argparse
import json
import os
import sys
import tempfile
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cases", type=int, default=20_000)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port")
    args = ap.parse_args()

    from repro.core.eventframe import CASE, EventFrame
    from repro.data import synthetic
    from repro.service import Ingestor, serve
    from repro.storage import edf

    frame, tables = synthetic.generate(num_cases=args.cases,
                                       num_activities=10, seed=42)
    case = np.asarray(frame[CASE])
    bounds = np.flatnonzero(case[1:] != case[:-1]) + 1
    per = max(1, len(bounds) // args.batches)
    cuts = [0] + [int(bounds[i]) for i in range(per - 1, len(bounds), per)]
    if cuts[-1] != frame.nrows:
        cuts.append(frame.nrows)
    print(f"log: {frame.nrows} events, {args.cases} cases, "
          f"{len(cuts) - 1} batches")

    root = tempfile.mkdtemp(prefix="repro-serving-")
    spool, parts = os.path.join(root, "spool"), os.path.join(root, "parts")
    os.makedirs(spool)

    def produce():
        """The event stream: one batch file lands every 200 ms."""
        for i in range(len(cuts) - 1):
            a, b = cuts[i], cuts[i + 1]
            batch = EventFrame(
                {k: v[a:b] for k, v in frame.columns.items()},
                {k: v[a:b] for k, v in frame.valid.items()})
            edf.write(os.path.join(spool, f"batch_{i:04d}.edf"), batch,
                      tables, version=3)
            print(f"  producer: batch {i} ({b - a} events)")
            time.sleep(0.2)

    ingestor = Ingestor(parts, spool, poll_interval=0.05).start()
    httpd = serve(ingestor, port=args.port, case_capacity=args.cases)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    print(f"serving on http://127.0.0.1:{port}\n")

    producer = threading.Thread(target=produce)
    producer.start()

    def get(path):
        with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}",
                                    timeout=60) as r:
            return json.loads(r.read())

    # query while the log grows: each response names its snapshot
    for _ in range(6):
        time.sleep(0.3)
        try:
            out = get("/collect?verb=dfg&engine=streaming")
        except urllib.error.HTTPError as e:     # 503 while spinning up
            print(f"  client: not ready yet ({e.code})")
            continue
        rep = out["report"]
        print(f"  client: dfg over {out['snapshot']['rows']} rows "
              f"(groups: {rep['groups_cached']} cached, "
              f"{rep['groups_folded']} folded, "
              f"{out['elapsed_us'] / 1000:.1f} ms)")

    producer.join()
    while ingestor.run_once():
        pass

    health = get("/health")
    print(f"\nfinal: {health['rows']} rows in {len(health['files'])} "
          f"partition(s); {health['requests']} requests, "
          f"{health['ingested']} batches ingested")
    top = get("/collect?verb=activity_counts")
    counts = top["result"]
    acts = tables["concept:name"]
    order = np.argsort(counts)[::-1][:5]
    print("top activities:",
          ", ".join(f"{acts[i]}={int(counts[i])}" for i in order))
    httpd.shutdown()
    ingestor.stop()


if __name__ == "__main__":
    main()
